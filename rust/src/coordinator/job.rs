//! Job model for the alignment service.

use crate::gw::GradientKind;
use crate::linalg::Mat;
use std::time::{Duration, Instant};

/// Monotonic job identifier.
pub type JobId = u64;

/// What a client asks the service to compute.
#[derive(Clone, Debug)]
pub enum JobPayload {
    /// GW between two distributions on 1D unit grids (equal size).
    Gw1d {
        /// Source distribution.
        u: Vec<f64>,
        /// Target distribution.
        v: Vec<f64>,
        /// Distance exponent.
        k: u32,
        /// Entropic ε.
        epsilon: f64,
    },
    /// FGW on 1D grids with a feature cost.
    Fgw1d {
        /// Source distribution.
        u: Vec<f64>,
        /// Target distribution.
        v: Vec<f64>,
        /// Feature cost matrix `C`.
        feature_cost: Mat,
        /// Linear/quadratic trade-off θ.
        theta: f64,
        /// Distance exponent.
        k: u32,
        /// Entropic ε.
        epsilon: f64,
    },
    /// GW between distributions on `n×n` 2D grids.
    Gw2d {
        /// Grid side length (`u`, `v` have length `n²`).
        n: usize,
        /// Source distribution (flattened row-major).
        u: Vec<f64>,
        /// Target distribution.
        v: Vec<f64>,
        /// Distance exponent.
        k: u32,
        /// Entropic ε.
        epsilon: f64,
    },
    /// GW between distributions on arbitrary dense metric spaces — the
    /// workload the low-rank backend serves (no grid structure to
    /// exploit). Build with [`JobPayload::gw_dense`], which stamps the
    /// content fingerprint at admission.
    GwDense {
        /// Source distance matrix (`u.len()` square, symmetric).
        dx: Mat,
        /// Target distance matrix (`v.len()` square, symmetric).
        dy: Mat,
        /// Source distribution.
        u: Vec<f64>,
        /// Target distribution.
        v: Vec<f64>,
        /// Entropic ε.
        epsilon: f64,
        /// FNV-1a-style content fingerprint over `(rows, cols, matrix
        /// words)` of both distance matrices, stamped once at
        /// admission ([`dense_fingerprint`]). The coordinator's
        /// warm-batch sub-split compares fingerprints instead of
        /// running an `O(N²)` matrix-equality check per pair; the full
        /// compare still runs on a fingerprint match (collision
        /// guard), so a stale or hand-rolled fingerprint can cost
        /// batching but never correctness.
        fingerprint: u64,
    },
}

/// FNV-1a-style fold over `(rows, cols, matrix words)` of both
/// distance matrices — the dense payload's content identity, computed
/// once at admission so same-geometry jobs batch without `O(N²)`
/// compares per pair. Each `f64` contributes its full bit pattern as
/// one XOR-multiply step (the FNV-1a offset/prime, folded per 64-bit
/// word rather than per byte — 8× fewer multiplies on the admission
/// path, with the same stability and avalanche-by-multiplication).
pub fn dense_fingerprint(dx: &Mat, dy: &Mat) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    let mut fold = |w: u64| {
        h ^= w;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    };
    for m in [dx, dy] {
        fold(m.rows() as u64);
        fold(m.cols() as u64);
        for &x in m.as_slice() {
            fold(x.to_bits());
        }
    }
    h
}

impl JobPayload {
    /// Build a dense-geometry GW payload, computing the content
    /// fingerprint over both distance matrices at admission.
    pub fn gw_dense(dx: Mat, dy: Mat, u: Vec<f64>, v: Vec<f64>, epsilon: f64) -> JobPayload {
        let fingerprint = dense_fingerprint(&dx, &dy);
        JobPayload::GwDense {
            dx,
            dy,
            u,
            v,
            epsilon,
            fingerprint,
        }
    }

    /// Problem size (support points per side).
    pub fn points(&self) -> usize {
        match self {
            JobPayload::Gw1d { u, .. } => u.len(),
            JobPayload::Fgw1d { u, .. } => u.len(),
            JobPayload::Gw2d { n, .. } => n * n,
            JobPayload::GwDense { u, .. } => u.len(),
        }
    }

    /// True iff the payload's geometry carries grid structure the FGC
    /// backend can exploit.
    pub fn is_structured(&self) -> bool {
        !matches!(self, JobPayload::GwDense { .. })
    }

    /// The job's entropic ε (a solver-config knob, so same-variant
    /// jobs only share a warm workspace batch when it matches too).
    pub fn epsilon(&self) -> f64 {
        match self {
            JobPayload::Gw1d { epsilon, .. }
            | JobPayload::Fgw1d { epsilon, .. }
            | JobPayload::Gw2d { epsilon, .. }
            | JobPayload::GwDense { epsilon, .. } => *epsilon,
        }
    }

    /// Quick structural validation before enqueueing.
    pub fn validate(&self) -> Result<(), String> {
        let check_dist = |w: &[f64], name: &str| -> Result<(), String> {
            if w.is_empty() {
                return Err(format!("{name} is empty"));
            }
            if w.iter().any(|&x| x < 0.0 || !x.is_finite()) {
                return Err(format!("{name} has negative/non-finite entries"));
            }
            let s: f64 = w.iter().sum();
            if (s - 1.0).abs() > 1e-6 {
                return Err(format!("{name} sums to {s}, expected 1"));
            }
            Ok(())
        };
        match self {
            JobPayload::Gw1d { u, v, epsilon, .. } => {
                check_dist(u, "u")?;
                check_dist(v, "v")?;
                if u.len() != v.len() {
                    return Err("u/v size mismatch (1D jobs use equal grids)".into());
                }
                if *epsilon <= 0.0 {
                    return Err("epsilon must be > 0".into());
                }
            }
            JobPayload::Fgw1d {
                u,
                v,
                feature_cost,
                theta,
                epsilon,
                ..
            } => {
                check_dist(u, "u")?;
                check_dist(v, "v")?;
                if feature_cost.shape() != (u.len(), v.len()) {
                    return Err("feature cost shape mismatch".into());
                }
                if !(0.0..=1.0).contains(theta) {
                    return Err("theta must be in [0,1]".into());
                }
                if *epsilon <= 0.0 {
                    return Err("epsilon must be > 0".into());
                }
            }
            JobPayload::Gw2d { n, u, v, epsilon, .. } => {
                check_dist(u, "u")?;
                check_dist(v, "v")?;
                if u.len() != n * n || v.len() != n * n {
                    return Err(format!("2D job needs n²={} entries", n * n));
                }
                if *epsilon <= 0.0 {
                    return Err("epsilon must be > 0".into());
                }
            }
            JobPayload::GwDense {
                dx,
                dy,
                u,
                v,
                epsilon,
                ..
            } => {
                check_dist(u, "u")?;
                check_dist(v, "v")?;
                if dx.shape() != (u.len(), u.len()) {
                    return Err(format!(
                        "dx must be {0}x{0} to match u, got {1:?}",
                        u.len(),
                        dx.shape()
                    ));
                }
                if dy.shape() != (v.len(), v.len()) {
                    return Err(format!(
                        "dy must be {0}x{0} to match v, got {1:?}",
                        v.len(),
                        dy.shape()
                    ));
                }
                if !dx.all_finite() || !dy.all_finite() {
                    return Err("distance matrices must be finite".into());
                }
                if *epsilon <= 0.0 {
                    return Err("epsilon must be > 0".into());
                }
            }
        }
        Ok(())
    }
}

/// Which backend executed (or will execute) a job.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum BackendChoice {
    /// Native Rust solver with the FGC gradient.
    NativeFgc,
    /// Native Rust solver with the dense baseline gradient.
    NativeNaive,
    /// Native Rust solver with the low-rank factored gradient.
    NativeLowRank,
    /// PJRT-compiled artifact (by name).
    Pjrt(String),
}

impl BackendChoice {
    /// The native choice for a gradient kind.
    pub fn native(kind: GradientKind) -> Self {
        match kind {
            GradientKind::Fgc => BackendChoice::NativeFgc,
            GradientKind::Naive => BackendChoice::NativeNaive,
            GradientKind::LowRank => BackendChoice::NativeLowRank,
        }
    }

    /// The gradient kind a native worker should run this choice with
    /// (PJRT falls back to FGC when executed natively).
    pub fn gradient_kind(&self) -> GradientKind {
        match self {
            BackendChoice::NativeNaive => GradientKind::Naive,
            BackendChoice::NativeLowRank => GradientKind::LowRank,
            BackendChoice::NativeFgc | BackendChoice::Pjrt(_) => GradientKind::Fgc,
        }
    }
}

impl std::fmt::Display for BackendChoice {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            BackendChoice::NativeFgc => write!(f, "native-fgc"),
            BackendChoice::NativeNaive => write!(f, "native-naive"),
            BackendChoice::NativeLowRank => write!(f, "native-lowrank"),
            BackendChoice::Pjrt(name) => write!(f, "pjrt:{name}"),
        }
    }
}

/// An enqueued job.
#[derive(Clone, Debug)]
pub struct JobRequest {
    /// Assigned id.
    pub id: JobId,
    /// The work.
    pub payload: JobPayload,
    /// Backend decided by the router at submit time.
    pub backend: BackendChoice,
    /// Enqueue timestamp (for queue-time accounting).
    pub submitted_at: Instant,
}

/// Completed-job report sent back to the submitter.
#[derive(Clone, Debug)]
pub struct JobResult {
    /// Job id.
    pub id: JobId,
    /// Final objective ((F)GW² value), if the solve succeeded.
    pub objective: Result<f64, String>,
    /// Transport plan (present on success and when the client asked
    /// for plans — always returned here; large-plan elision is a
    /// client-side concern).
    pub plan: Option<Mat>,
    /// Which backend ran it.
    pub backend: BackendChoice,
    /// Time spent queued.
    pub queue_time: Duration,
    /// Time spent solving.
    pub solve_time: Duration,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn uniform(n: usize) -> Vec<f64> {
        vec![1.0 / n as f64; n]
    }

    #[test]
    fn validate_accepts_good_jobs() {
        let p = JobPayload::Gw1d {
            u: uniform(8),
            v: uniform(8),
            k: 1,
            epsilon: 0.002,
        };
        assert!(p.validate().is_ok());
        assert_eq!(p.points(), 8);
    }

    #[test]
    fn validate_rejects_bad_marginals() {
        let p = JobPayload::Gw1d {
            u: vec![0.5, 0.6],
            v: uniform(2),
            k: 1,
            epsilon: 0.002,
        };
        assert!(p.validate().is_err());
        let p = JobPayload::Gw1d {
            u: vec![],
            v: vec![],
            k: 1,
            epsilon: 0.002,
        };
        assert!(p.validate().is_err());
    }

    #[test]
    fn validate_rejects_bad_fgw() {
        let p = JobPayload::Fgw1d {
            u: uniform(4),
            v: uniform(4),
            feature_cost: Mat::zeros(3, 4),
            theta: 0.5,
            k: 1,
            epsilon: 0.01,
        };
        assert!(p.validate().is_err());
        let p = JobPayload::Fgw1d {
            u: uniform(4),
            v: uniform(4),
            feature_cost: Mat::zeros(4, 4),
            theta: 1.5,
            k: 1,
            epsilon: 0.01,
        };
        assert!(p.validate().is_err());
    }

    #[test]
    fn validate_dense_jobs() {
        let good = JobPayload::gw_dense(
            Mat::zeros(4, 4),
            Mat::zeros(4, 4),
            uniform(4),
            uniform(4),
            0.01,
        );
        assert!(good.validate().is_ok());
        assert_eq!(good.points(), 4);
        assert!(!good.is_structured());
        let bad_shape = JobPayload::gw_dense(
            Mat::zeros(3, 4),
            Mat::zeros(4, 4),
            uniform(4),
            uniform(4),
            0.01,
        );
        assert!(bad_shape.validate().is_err());
        let mut nan = Mat::zeros(4, 4);
        nan[(0, 0)] = f64::NAN;
        let bad_entries =
            JobPayload::gw_dense(nan, Mat::zeros(4, 4), uniform(4), uniform(4), 0.01);
        assert!(bad_entries.validate().is_err());
    }

    #[test]
    fn dense_fingerprint_tracks_content_and_shape() {
        let a = Mat::from_fn(4, 4, |i, j| (i + 2 * j) as f64 * 0.5);
        let b = a.map(|x| x + 1e-12); // tiny perturbation, new bytes
        let fp = dense_fingerprint;
        assert_eq!(fp(&a, &a), fp(&a.clone(), &a.clone()), "deterministic");
        assert_ne!(fp(&a, &a), fp(&b, &a), "content change must move the hash");
        assert_ne!(fp(&a, &a), fp(&a, &b), "either side participates");
        // Shape participates even when the bytes prefix agrees.
        let wide = Mat::zeros(2, 8);
        let tall = Mat::zeros(8, 2);
        assert_ne!(fp(&wide, &wide), fp(&tall, &tall));
        // The constructor stamps the same hash.
        let payload = JobPayload::gw_dense(
            a.clone(),
            a.clone(),
            uniform(4),
            uniform(4),
            0.01,
        );
        match payload {
            JobPayload::GwDense { fingerprint, .. } => assert_eq!(fingerprint, fp(&a, &a)),
            _ => unreachable!(),
        }
    }

    #[test]
    fn backend_choice_round_trips_kinds() {
        for kind in [GradientKind::Fgc, GradientKind::Naive, GradientKind::LowRank] {
            assert_eq!(BackendChoice::native(kind).gradient_kind(), kind);
        }
        assert_eq!(
            BackendChoice::Pjrt("x".into()).gradient_kind(),
            GradientKind::Fgc
        );
    }

    #[test]
    fn validate_rejects_bad_2d_size() {
        let p = JobPayload::Gw2d {
            n: 3,
            u: uniform(8),
            v: uniform(9),
            k: 1,
            epsilon: 0.004,
        };
        assert!(p.validate().is_err());
    }
}
