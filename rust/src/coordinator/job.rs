//! Job model for the alignment service.

use crate::gw::{CouplingRank, Geometry, GradientKind, Precision};
use crate::linalg::Mat;
use std::time::{Duration, Instant};

/// Monotonic job identifier.
pub type JobId = u64;

/// What a client asks the service to compute.
#[derive(Clone, Debug)]
pub enum JobPayload {
    /// GW between two distributions on 1D unit grids (equal size).
    Gw1d {
        /// Source distribution.
        u: Vec<f64>,
        /// Target distribution.
        v: Vec<f64>,
        /// Distance exponent.
        k: u32,
        /// Entropic ε.
        epsilon: f64,
    },
    /// FGW on 1D grids with a feature cost.
    Fgw1d {
        /// Source distribution.
        u: Vec<f64>,
        /// Target distribution.
        v: Vec<f64>,
        /// Feature cost matrix `C`.
        feature_cost: Mat,
        /// Linear/quadratic trade-off θ.
        theta: f64,
        /// Distance exponent.
        k: u32,
        /// Entropic ε.
        epsilon: f64,
    },
    /// GW between distributions on `n×n` 2D grids.
    Gw2d {
        /// Grid side length (`u`, `v` have length `n²`).
        n: usize,
        /// Source distribution (flattened row-major).
        u: Vec<f64>,
        /// Target distribution.
        v: Vec<f64>,
        /// Distance exponent.
        k: u32,
        /// Entropic ε.
        epsilon: f64,
    },
    /// GW between distributions on `n×n×n` 3D grids (volumetric
    /// data; scans through the separable fgc engine like 1D/2D).
    Gw3d {
        /// Grid side length (`u`, `v` have length `n³`).
        n: usize,
        /// Source distribution (flattened `(z·n + y)·n + x`).
        u: Vec<f64>,
        /// Target distribution.
        v: Vec<f64>,
        /// Distance exponent.
        k: u32,
        /// Entropic ε.
        epsilon: f64,
    },
    /// GW between an arbitrary dense metric support (source side) and
    /// a grid geometry (target side) — the image/volume-vs-point-cloud
    /// shape the separable engine scans on its structured side
    /// (barycenter-style traffic served through the coordinator).
    /// Build with [`JobPayload::gw_mixed`], which stamps the dense
    /// side's content fingerprint at admission.
    GwMixed {
        /// Source distance matrix (`u.len()` square, symmetric).
        dx: Mat,
        /// Target-side grid geometry (must be a grid variant — 1D, 2D
        /// or 3D; [`JobPayload::validate`] rejects dense here, that is
        /// [`JobPayload::GwDense`]'s job).
        grid: Geometry,
        /// Source distribution.
        u: Vec<f64>,
        /// Target distribution.
        v: Vec<f64>,
        /// Entropic ε.
        epsilon: f64,
        /// FNV-1a-style content fingerprint over `(rows, cols, matrix
        /// words)` of the dense side, stamped once at admission
        /// ([`mixed_fingerprint`]). The grid side is compared by its
        /// `O(1)` descriptor; the dense side by this `u64`, with the
        /// full matrix compare only on a fingerprint match (collision
        /// guard) — a stale fingerprint can cost batching, never
        /// correctness.
        fingerprint: u64,
    },
    /// GW between distributions on arbitrary dense metric spaces — the
    /// workload the low-rank backend serves (no grid structure to
    /// exploit). Build with [`JobPayload::gw_dense`], which stamps the
    /// content fingerprint at admission.
    GwDense {
        /// Source distance matrix (`u.len()` square, symmetric).
        dx: Mat,
        /// Target distance matrix (`v.len()` square, symmetric).
        dy: Mat,
        /// Source distribution.
        u: Vec<f64>,
        /// Target distribution.
        v: Vec<f64>,
        /// Entropic ε.
        epsilon: f64,
        /// FNV-1a-style content fingerprint over `(rows, cols, matrix
        /// words)` of both distance matrices, stamped once at
        /// admission ([`dense_fingerprint`]). The coordinator's
        /// warm-batch sub-split compares fingerprints instead of
        /// running an `O(N²)` matrix-equality check per pair; the full
        /// compare still runs on a fingerprint match (collision
        /// guard), so a stale or hand-rolled fingerprint can cost
        /// batching but never correctness.
        fingerprint: u64,
    },
    /// Sliced-GW screening: rank `candidates.len()` point clouds
    /// against `query` with the O(N log N) sliced surrogate
    /// ([`crate::gw::sliced`]), then escalate only the `top_k` best to
    /// the exact entropic solver. The retrieval workload — one query,
    /// many candidates, exact answers only where they matter. Build
    /// with [`JobPayload::gw_screen`], which stamps the content
    /// fingerprint at admission.
    GwScreen {
        /// Query point cloud (`P×d` coordinates, one point per row).
        query: Mat,
        /// Candidate point clouds (`n_c×d` each, same `d` as the
        /// query).
        candidates: Vec<Mat>,
        /// How many screened candidates escalate to exact solves
        /// (`1 ≤ top_k ≤ candidates.len()`).
        top_k: usize,
        /// Slice count; `0` lets the coordinator's ScreenPolicy
        /// ([`crate::gw::backend::cost_model::screen_slices`]) choose
        /// from the job's deadline budget.
        slices: usize,
        /// Seed each escalated exact solve from the best slice's
        /// monotone plan. Off by default: cold escalation is
        /// bit-for-bit with a direct library solve.
        warm_start: bool,
        /// Entropic ε for the escalated exact solves.
        epsilon: f64,
        /// FNV-1a-style content fingerprint over the query and every
        /// candidate cloud ([`screen_fingerprint`]), stamped once at
        /// admission. Same contract as [`JobPayload::GwDense`]'s: the
        /// warm-batch sub-split compares fingerprints, with the full
        /// compare only on a match, so a stale hash can cost batching
        /// but never correctness.
        fingerprint: u64,
    },
}

/// One escalated screening hit: a candidate that survived the sliced
/// ranking and got an exact entropic solve.
#[derive(Clone, Debug)]
pub struct ScreenHit {
    /// Index into the payload's `candidates`.
    pub candidate: usize,
    /// Sliced surrogate score (mean over directions of the 1D GW
    /// cost) that earned the escalation.
    pub sliced_score: f64,
    /// Exact entropic GW² objective from the escalated solve.
    pub objective: f64,
}

/// Screening report attached to a [`JobResult`] for
/// [`JobPayload::GwScreen`] jobs (`None` for every other payload).
#[derive(Clone, Debug)]
pub struct ScreenOutcome {
    /// Sliced surrogate score per candidate, payload order.
    pub scores: Vec<f64>,
    /// Escalated hits, best exact objective first. The top result's
    /// plan rides in [`JobResult::plan`].
    pub hits: Vec<ScreenHit>,
    /// Slice count the screen actually ran with (the requested count,
    /// or the ScreenPolicy's pick when the payload asked for `0`).
    pub slices: usize,
}

/// One FNV-1a-style XOR-multiply fold of a matrix's `(rows, cols,
/// words)` into a running hash. Each `f64` contributes its full bit
/// pattern as one step (the FNV-1a offset/prime, folded per 64-bit
/// word rather than per byte — 8× fewer multiplies on the admission
/// path, with the same stability and avalanche-by-multiplication).
fn fold_mat(h: &mut u64, m: &Mat) {
    let mut fold = |w: u64| {
        *h ^= w;
        *h = h.wrapping_mul(0x0000_0100_0000_01b3);
    };
    fold(m.rows() as u64);
    fold(m.cols() as u64);
    for &x in m.as_slice() {
        fold(x.to_bits());
    }
}

/// FNV-1a offset basis (the fold's starting hash).
const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;

/// Content fingerprint over both distance matrices of a
/// [`JobPayload::GwDense`] payload — computed once at admission so
/// same-geometry jobs batch without `O(N²)` compares per pair.
pub fn dense_fingerprint(dx: &Mat, dy: &Mat) -> u64 {
    let mut h = FNV_OFFSET;
    fold_mat(&mut h, dx);
    fold_mat(&mut h, dy);
    h
}

/// Content fingerprint over the dense side of a
/// [`JobPayload::GwMixed`] payload (the grid side is an `O(1)`
/// descriptor compared directly — only the dense matrix needs a
/// content hash).
pub fn mixed_fingerprint(dx: &Mat) -> u64 {
    let mut h = FNV_OFFSET;
    fold_mat(&mut h, dx);
    h
}

/// Content fingerprint over the query and every candidate cloud of a
/// [`JobPayload::GwScreen`] payload. The candidate count folds in
/// first so `[a, b]` and `[a]`+`b`-in-query style reshuffles cannot
/// collide by concatenation.
pub fn screen_fingerprint(query: &Mat, candidates: &[Mat]) -> u64 {
    let mut h = FNV_OFFSET;
    h ^= candidates.len() as u64;
    h = h.wrapping_mul(0x0000_0100_0000_01b3);
    fold_mat(&mut h, query);
    for c in candidates {
        fold_mat(&mut h, c);
    }
    h
}

impl JobPayload {
    /// Build a dense-geometry GW payload, computing the content
    /// fingerprint over both distance matrices at admission.
    pub fn gw_dense(dx: Mat, dy: Mat, u: Vec<f64>, v: Vec<f64>, epsilon: f64) -> JobPayload {
        let fingerprint = dense_fingerprint(&dx, &dy);
        JobPayload::GwDense {
            dx,
            dy,
            u,
            v,
            epsilon,
            fingerprint,
        }
    }

    /// Build a mixed dense×grid GW payload, computing the dense side's
    /// content fingerprint at admission.
    pub fn gw_mixed(
        dx: Mat,
        grid: Geometry,
        u: Vec<f64>,
        v: Vec<f64>,
        epsilon: f64,
    ) -> JobPayload {
        let fingerprint = mixed_fingerprint(&dx);
        JobPayload::GwMixed {
            dx,
            grid,
            u,
            v,
            epsilon,
            fingerprint,
        }
    }

    /// Build a sliced-screening payload, computing the content
    /// fingerprint over the query and all candidates at admission.
    /// `slices = 0` defers the slice count to the ScreenPolicy.
    pub fn gw_screen(
        query: Mat,
        candidates: Vec<Mat>,
        top_k: usize,
        slices: usize,
        warm_start: bool,
        epsilon: f64,
    ) -> JobPayload {
        let fingerprint = screen_fingerprint(&query, &candidates);
        JobPayload::GwScreen {
            query,
            candidates,
            top_k,
            slices,
            warm_start,
            epsilon,
            fingerprint,
        }
    }

    /// Problem size (source-side support points).
    pub fn points(&self) -> usize {
        match self {
            JobPayload::Gw1d { u, .. } => u.len(),
            JobPayload::Fgw1d { u, .. } => u.len(),
            JobPayload::Gw2d { n, .. } => n * n,
            JobPayload::Gw3d { n, .. } => n * n * n,
            JobPayload::GwDense { u, .. } => u.len(),
            JobPayload::GwMixed { u, .. } => u.len(),
            JobPayload::GwScreen { query, .. } => query.rows(),
        }
    }

    /// Target-side support points (admission resolves the coupling
    /// representation against both sides' sizes).
    pub fn target_points(&self) -> usize {
        match self {
            JobPayload::Gw1d { v, .. } => v.len(),
            JobPayload::Fgw1d { v, .. } => v.len(),
            JobPayload::Gw2d { n, .. } => n * n,
            JobPayload::Gw3d { n, .. } => n * n * n,
            JobPayload::GwDense { v, .. } => v.len(),
            JobPayload::GwMixed { v, .. } => v.len(),
            // The escalated exact solves are query-vs-candidate; size
            // the target side by the largest candidate.
            JobPayload::GwScreen { candidates, .. } => {
                candidates.iter().map(Mat::rows).max().unwrap_or(0)
            }
        }
    }

    /// True iff the payload's geometry carries grid structure the FGC
    /// backend can exploit on at least one side (only fully dense
    /// payloads carry none — the separable engine scans any grid
    /// side, including the mixed payload's).
    pub fn is_structured(&self) -> bool {
        !matches!(
            self,
            JobPayload::GwDense { .. } | JobPayload::GwScreen { .. }
        )
    }

    /// Coarse variant family for latency accounting and the wire
    /// layer's `/metrics` histograms — one label per serving tier, so
    /// the cardinality stays fixed (six families) no matter what
    /// shapes clients submit. FGW rides with its grid family (same
    /// geometry, same solve loop); the mixed payload's 1D/2D/3D
    /// structured sides share one family (the warm-cache key still
    /// splits them — this is an observability bucket, not an identity).
    pub fn family(&self) -> &'static str {
        match self {
            JobPayload::Gw1d { .. } | JobPayload::Fgw1d { .. } => "grid1d",
            JobPayload::Gw2d { .. } => "grid2d",
            JobPayload::Gw3d { .. } => "grid3d",
            JobPayload::GwDense { .. } => "dense",
            JobPayload::GwMixed { .. } => "mixed",
            JobPayload::GwScreen { .. } => "screen",
        }
    }

    /// The job's entropic ε (a solver-config knob, so same-variant
    /// jobs only share a warm workspace batch when it matches too).
    pub fn epsilon(&self) -> f64 {
        match self {
            JobPayload::Gw1d { epsilon, .. }
            | JobPayload::Fgw1d { epsilon, .. }
            | JobPayload::Gw2d { epsilon, .. }
            | JobPayload::Gw3d { epsilon, .. }
            | JobPayload::GwDense { epsilon, .. }
            | JobPayload::GwMixed { epsilon, .. }
            | JobPayload::GwScreen { epsilon, .. } => *epsilon,
        }
    }

    /// Quick structural validation before enqueueing.
    pub fn validate(&self) -> Result<(), String> {
        let check_dist = |w: &[f64], name: &str| -> Result<(), String> {
            if w.is_empty() {
                return Err(format!("{name} is empty"));
            }
            if w.iter().any(|&x| x < 0.0 || !x.is_finite()) {
                return Err(format!("{name} has negative/non-finite entries"));
            }
            let s: f64 = w.iter().sum();
            if (s - 1.0).abs() > 1e-6 {
                return Err(format!("{name} sums to {s}, expected 1"));
            }
            Ok(())
        };
        match self {
            JobPayload::Gw1d { u, v, epsilon, .. } => {
                check_dist(u, "u")?;
                check_dist(v, "v")?;
                if u.len() != v.len() {
                    return Err("u/v size mismatch (1D jobs use equal grids)".into());
                }
                if u.len() < 2 {
                    return Err("1D grids need at least 2 points".into());
                }
                if *epsilon <= 0.0 {
                    return Err("epsilon must be > 0".into());
                }
            }
            JobPayload::Fgw1d {
                u,
                v,
                feature_cost,
                theta,
                epsilon,
                ..
            } => {
                check_dist(u, "u")?;
                check_dist(v, "v")?;
                if u.len() < 2 || v.len() < 2 {
                    return Err("1D grids need at least 2 points".into());
                }
                if feature_cost.shape() != (u.len(), v.len()) {
                    return Err("feature cost shape mismatch".into());
                }
                if !(0.0..=1.0).contains(theta) {
                    return Err("theta must be in [0,1]".into());
                }
                if *epsilon <= 0.0 {
                    return Err("epsilon must be > 0".into());
                }
            }
            JobPayload::Gw2d { n, u, v, epsilon, .. } => {
                check_dist(u, "u")?;
                check_dist(v, "v")?;
                // The unit-grid constructors the worker builds from
                // assert n ≥ 2; reject here so a bad job cannot panic
                // a worker thread.
                if *n < 2 {
                    return Err("2D grids need side length ≥ 2".into());
                }
                if u.len() != n * n || v.len() != n * n {
                    return Err(format!("2D job needs n²={} entries", n * n));
                }
                if *epsilon <= 0.0 {
                    return Err("epsilon must be > 0".into());
                }
            }
            JobPayload::Gw3d { n, u, v, epsilon, .. } => {
                check_dist(u, "u")?;
                check_dist(v, "v")?;
                if *n < 2 {
                    return Err("3D grids need side length ≥ 2".into());
                }
                let n3 = n * n * n;
                if u.len() != n3 || v.len() != n3 {
                    return Err(format!("3D job needs n³={n3} entries"));
                }
                if *epsilon <= 0.0 {
                    return Err("epsilon must be > 0".into());
                }
            }
            JobPayload::GwMixed {
                dx,
                grid,
                u,
                v,
                epsilon,
                ..
            } => {
                check_dist(u, "u")?;
                check_dist(v, "v")?;
                if !grid.is_structured() {
                    return Err(
                        "mixed job needs a grid geometry on its structured side \
                         (use a GwDense payload for dense×dense pairs)"
                            .into(),
                    );
                }
                // Grid structs have public fields, so a client can
                // bypass the constructor asserts; reject degenerate
                // descriptors here like the pure-grid payloads do
                // (`None` cannot occur — dense was rejected above —
                // but fails closed anyway).
                match grid.grid_dims() {
                    Some((n, h)) if n >= 2 && h.is_finite() && h > 0.0 => {}
                    _ => {
                        return Err(
                            "grid side needs n ≥ 2 points and finite positive spacing".into(),
                        )
                    }
                }
                if dx.shape() != (u.len(), u.len()) {
                    return Err(format!(
                        "dx must be {0}x{0} to match u, got {1:?}",
                        u.len(),
                        dx.shape()
                    ));
                }
                if grid.len() != v.len() {
                    return Err(format!(
                        "grid side has {} points but v has {}",
                        grid.len(),
                        v.len()
                    ));
                }
                if !dx.all_finite() {
                    return Err("distance matrix must be finite".into());
                }
                if *epsilon <= 0.0 {
                    return Err("epsilon must be > 0".into());
                }
            }
            JobPayload::GwDense {
                dx,
                dy,
                u,
                v,
                epsilon,
                ..
            } => {
                check_dist(u, "u")?;
                check_dist(v, "v")?;
                if dx.shape() != (u.len(), u.len()) {
                    return Err(format!(
                        "dx must be {0}x{0} to match u, got {1:?}",
                        u.len(),
                        dx.shape()
                    ));
                }
                if dy.shape() != (v.len(), v.len()) {
                    return Err(format!(
                        "dy must be {0}x{0} to match v, got {1:?}",
                        v.len(),
                        dy.shape()
                    ));
                }
                if !dx.all_finite() || !dy.all_finite() {
                    return Err("distance matrices must be finite".into());
                }
                if *epsilon <= 0.0 {
                    return Err("epsilon must be > 0".into());
                }
            }
            JobPayload::GwScreen {
                query,
                candidates,
                top_k,
                epsilon,
                ..
            } => {
                if query.rows() == 0 || query.cols() == 0 {
                    return Err("query cloud is empty".into());
                }
                if !query.all_finite() {
                    return Err("query cloud must be finite".into());
                }
                if candidates.is_empty() {
                    return Err("screen needs at least one candidate".into());
                }
                for (c, cand) in candidates.iter().enumerate() {
                    if cand.rows() == 0 {
                        return Err(format!("candidate {c} is empty"));
                    }
                    if cand.cols() != query.cols() {
                        return Err(format!(
                            "candidate {c} has {} coordinates, query has {}",
                            cand.cols(),
                            query.cols()
                        ));
                    }
                    if !cand.all_finite() {
                        return Err(format!("candidate {c} must be finite"));
                    }
                }
                if *top_k == 0 || *top_k > candidates.len() {
                    return Err(format!(
                        "top_k must be in 1..={}, got {top_k}",
                        candidates.len()
                    ));
                }
                if *epsilon <= 0.0 {
                    return Err("epsilon must be > 0".into());
                }
            }
        }
        Ok(())
    }
}

/// Which backend executed (or will execute) a job.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum BackendChoice {
    /// Native Rust solver with the FGC gradient.
    NativeFgc,
    /// Native Rust solver with the dense baseline gradient.
    NativeNaive,
    /// Native Rust solver with the low-rank factored gradient.
    NativeLowRank,
    /// PJRT-compiled artifact (by name).
    Pjrt(String),
}

impl BackendChoice {
    /// The native choice for a gradient kind.
    pub fn native(kind: GradientKind) -> Self {
        match kind {
            GradientKind::Fgc => BackendChoice::NativeFgc,
            GradientKind::Naive => BackendChoice::NativeNaive,
            GradientKind::LowRank => BackendChoice::NativeLowRank,
        }
    }

    /// The gradient kind a native worker should run this choice with
    /// (PJRT falls back to FGC when executed natively).
    pub fn gradient_kind(&self) -> GradientKind {
        match self {
            BackendChoice::NativeNaive => GradientKind::Naive,
            BackendChoice::NativeLowRank => GradientKind::LowRank,
            BackendChoice::NativeFgc | BackendChoice::Pjrt(_) => GradientKind::Fgc,
        }
    }
}

impl std::fmt::Display for BackendChoice {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            BackendChoice::NativeFgc => write!(f, "native-fgc"),
            BackendChoice::NativeNaive => write!(f, "native-naive"),
            BackendChoice::NativeLowRank => write!(f, "native-lowrank"),
            BackendChoice::Pjrt(name) => write!(f, "pjrt:{name}"),
        }
    }
}

/// Per-job serving options: wall-clock deadline and retry budget.
///
/// Threaded through `submit`/`submit_and_wait` into [`JobRequest`],
/// enforced at admission (deadline pressure maps onto the shard shed
/// budget), at dequeue (expired jobs get a terminal
/// `Error::Rejected`-style result instead of worker time), and between
/// outer iterations of a solo solve.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct JobOptions {
    /// Wall-clock budget measured from submission; `None` = no
    /// deadline (the job may queue and solve indefinitely).
    pub deadline: Option<Duration>,
    /// Maximum climbs of the numeric degradation ladder (log-domain
    /// retry → ε·2 annealed retry → naive-backend fallback) before a
    /// numeric failure is returned as-is. `0` fails fast.
    pub max_retries: u32,
    /// Solve-precision tier for this job. `None` inherits the
    /// service-wide default ([`crate::coordinator::CoordinatorConfig`]
    /// `precision`); admission resolves `Auto` against the job's shape
    /// and stores the concrete tier, so workers (and the warm-cache
    /// key) always see `Some(F64)` or `Some(F32Refine)`.
    pub precision: Option<Precision>,
    /// Coupling representation for this (pure-GW) job. `None` inherits
    /// the service-wide default
    /// ([`crate::coordinator::CoordinatorConfig`] `coupling`), which
    /// itself may be `None` = auto; admission resolves auto against
    /// the job's shape via
    /// [`crate::gw::backend::cost_model::auto_coupling_for_sizes`] and
    /// stores the concrete choice, so workers (and the warm-cache key)
    /// always see `Some(Full)` or `Some(LowRank(r))`. FGW jobs ignore
    /// the knob (always full-rank).
    pub coupling: Option<CouplingRank>,
}

impl Default for JobOptions {
    fn default() -> Self {
        JobOptions {
            deadline: None,
            max_retries: 3,
            precision: None,
            coupling: None,
        }
    }
}

/// An enqueued job.
#[derive(Clone, Debug)]
pub struct JobRequest {
    /// Assigned id.
    pub id: JobId,
    /// The work.
    pub payload: JobPayload,
    /// Backend decided by the router at submit time.
    pub backend: BackendChoice,
    /// Enqueue timestamp (for queue-time accounting).
    pub submitted_at: Instant,
    /// Deadline/retry options captured at submit time.
    pub options: JobOptions,
}

impl JobRequest {
    /// The instant at which this job's deadline passes, if it has one.
    pub fn deadline_instant(&self) -> Option<Instant> {
        self.options.deadline.map(|d| self.submitted_at + d)
    }

    /// True iff the job carries a deadline that has already passed.
    pub fn expired(&self) -> bool {
        match self.options.deadline {
            Some(d) => self.submitted_at.elapsed() >= d,
            None => false,
        }
    }
}

/// Completed-job report sent back to the submitter.
#[derive(Clone, Debug)]
pub struct JobResult {
    /// Job id.
    pub id: JobId,
    /// Final objective ((F)GW² value), if the solve succeeded.
    pub objective: Result<f64, String>,
    /// Transport plan (present on success and when the client asked
    /// for plans — always returned here; large-plan elision is a
    /// client-side concern).
    pub plan: Option<Mat>,
    /// Which backend ran it.
    pub backend: BackendChoice,
    /// Variant family of the payload ([`JobPayload::family`]),
    /// stamped so metrics and the wire layer can label the result
    /// without holding the (possibly large) payload.
    pub family: &'static str,
    /// Time spent queued.
    pub queue_time: Duration,
    /// Time spent solving.
    pub solve_time: Duration,
    /// Screening report: `Some` for [`JobPayload::GwScreen`] jobs
    /// (per-candidate sliced scores plus the escalated exact hits),
    /// `None` for every other payload. On a screen job `objective`
    /// and `plan` carry the best escalated hit's solve.
    pub screen: Option<ScreenOutcome>,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn uniform(n: usize) -> Vec<f64> {
        vec![1.0 / n as f64; n]
    }

    #[test]
    fn job_options_deadline_expiry() {
        let req = JobRequest {
            id: 1,
            payload: JobPayload::Gw1d {
                u: uniform(4),
                v: uniform(4),
                k: 1,
                epsilon: 0.01,
            },
            backend: BackendChoice::NativeFgc,
            submitted_at: Instant::now(),
            options: JobOptions::default(),
        };
        // No deadline: never expires, no deadline instant.
        assert!(!req.expired());
        assert!(req.deadline_instant().is_none());
        // Zero deadline: expired on arrival.
        let mut zero = req.clone();
        zero.options.deadline = Some(Duration::ZERO);
        assert!(zero.expired());
        assert_eq!(zero.deadline_instant(), Some(zero.submitted_at));
        // Generous deadline: live.
        let mut live = req;
        live.options.deadline = Some(Duration::from_secs(3600));
        assert!(!live.expired());
    }

    #[test]
    fn validate_accepts_good_jobs() {
        let p = JobPayload::Gw1d {
            u: uniform(8),
            v: uniform(8),
            k: 1,
            epsilon: 0.002,
        };
        assert!(p.validate().is_ok());
        assert_eq!(p.points(), 8);
    }

    #[test]
    fn validate_rejects_bad_marginals() {
        let p = JobPayload::Gw1d {
            u: vec![0.5, 0.6],
            v: uniform(2),
            k: 1,
            epsilon: 0.002,
        };
        assert!(p.validate().is_err());
        let p = JobPayload::Gw1d {
            u: vec![],
            v: vec![],
            k: 1,
            epsilon: 0.002,
        };
        assert!(p.validate().is_err());
    }

    #[test]
    fn validate_rejects_bad_fgw() {
        let p = JobPayload::Fgw1d {
            u: uniform(4),
            v: uniform(4),
            feature_cost: Mat::zeros(3, 4),
            theta: 0.5,
            k: 1,
            epsilon: 0.01,
        };
        assert!(p.validate().is_err());
        let p = JobPayload::Fgw1d {
            u: uniform(4),
            v: uniform(4),
            feature_cost: Mat::zeros(4, 4),
            theta: 1.5,
            k: 1,
            epsilon: 0.01,
        };
        assert!(p.validate().is_err());
    }

    #[test]
    fn validate_dense_jobs() {
        let good = JobPayload::gw_dense(
            Mat::zeros(4, 4),
            Mat::zeros(4, 4),
            uniform(4),
            uniform(4),
            0.01,
        );
        assert!(good.validate().is_ok());
        assert_eq!(good.points(), 4);
        assert!(!good.is_structured());
        let bad_shape = JobPayload::gw_dense(
            Mat::zeros(3, 4),
            Mat::zeros(4, 4),
            uniform(4),
            uniform(4),
            0.01,
        );
        assert!(bad_shape.validate().is_err());
        let mut nan = Mat::zeros(4, 4);
        nan[(0, 0)] = f64::NAN;
        let bad_entries =
            JobPayload::gw_dense(nan, Mat::zeros(4, 4), uniform(4), uniform(4), 0.01);
        assert!(bad_entries.validate().is_err());
    }

    #[test]
    fn dense_fingerprint_tracks_content_and_shape() {
        let a = Mat::from_fn(4, 4, |i, j| (i + 2 * j) as f64 * 0.5);
        let b = a.map(|x| x + 1e-12); // tiny perturbation, new bytes
        let fp = dense_fingerprint;
        assert_eq!(fp(&a, &a), fp(&a.clone(), &a.clone()), "deterministic");
        assert_ne!(fp(&a, &a), fp(&b, &a), "content change must move the hash");
        assert_ne!(fp(&a, &a), fp(&a, &b), "either side participates");
        // Shape participates even when the bytes prefix agrees.
        let wide = Mat::zeros(2, 8);
        let tall = Mat::zeros(8, 2);
        assert_ne!(fp(&wide, &wide), fp(&tall, &tall));
        // The constructor stamps the same hash.
        let payload = JobPayload::gw_dense(
            a.clone(),
            a.clone(),
            uniform(4),
            uniform(4),
            0.01,
        );
        match payload {
            JobPayload::GwDense { fingerprint, .. } => assert_eq!(fingerprint, fp(&a, &a)),
            _ => unreachable!(),
        }
    }

    #[test]
    fn validate_3d_jobs() {
        let good = JobPayload::Gw3d {
            n: 2,
            u: uniform(8),
            v: uniform(8),
            k: 1,
            epsilon: 0.01,
        };
        assert!(good.validate().is_ok());
        assert_eq!(good.points(), 8);
        assert!(good.is_structured());
        let bad = JobPayload::Gw3d {
            n: 2,
            u: uniform(8),
            v: uniform(9),
            k: 1,
            epsilon: 0.01,
        };
        assert!(bad.validate().is_err());
    }

    #[test]
    fn validate_rejects_degenerate_grids() {
        // The unit-grid constructors assert n ≥ 2, so admission must
        // reject single-point grids instead of panicking a worker.
        let gw1 = JobPayload::Gw1d {
            u: uniform(1),
            v: uniform(1),
            k: 1,
            epsilon: 0.01,
        };
        assert!(gw1.validate().is_err());
        let fgw1 = JobPayload::Fgw1d {
            u: uniform(1),
            v: uniform(1),
            feature_cost: Mat::zeros(1, 1),
            theta: 0.5,
            k: 1,
            epsilon: 0.01,
        };
        assert!(fgw1.validate().is_err());
        let gw2 = JobPayload::Gw2d {
            n: 1,
            u: uniform(1),
            v: uniform(1),
            k: 1,
            epsilon: 0.01,
        };
        assert!(gw2.validate().is_err());
        let gw3 = JobPayload::Gw3d {
            n: 1,
            u: uniform(1),
            v: uniform(1),
            k: 1,
            epsilon: 0.01,
        };
        assert!(gw3.validate().is_err());
    }

    #[test]
    fn validate_mixed_jobs() {
        let grid = crate::gw::Geometry::grid_2d_unit(3, 1); // 9 points
        let good = JobPayload::gw_mixed(
            Mat::zeros(4, 4),
            grid.clone(),
            uniform(4),
            uniform(9),
            0.01,
        );
        assert!(good.validate().is_ok(), "{:?}", good.validate());
        assert_eq!(good.points(), 4);
        assert!(good.is_structured());
        // Dense "grid" side is a GwDense payload's job, not this one's.
        let dense_side = JobPayload::gw_mixed(
            Mat::zeros(4, 4),
            crate::gw::Geometry::Dense(Mat::zeros(9, 9)),
            uniform(4),
            uniform(9),
            0.01,
        );
        assert!(dense_side.validate().is_err());
        // Grid/target-marginal size mismatch.
        let bad_v = JobPayload::gw_mixed(
            Mat::zeros(4, 4),
            grid.clone(),
            uniform(4),
            uniform(8),
            0.01,
        );
        assert!(bad_v.validate().is_err());
        // dx shape mismatch.
        let bad_dx =
            JobPayload::gw_mixed(Mat::zeros(3, 4), grid.clone(), uniform(4), uniform(9), 0.01);
        assert!(bad_dx.validate().is_err());
        // Non-finite dense side.
        let mut nan = Mat::zeros(4, 4);
        nan[(0, 0)] = f64::NAN;
        let bad_entries = JobPayload::gw_mixed(nan, grid, uniform(4), uniform(9), 0.01);
        assert!(bad_entries.validate().is_err());
        // Degenerate grid descriptors built around the constructor
        // asserts (pub fields) must be rejected, not solved on.
        let nan_h = JobPayload::gw_mixed(
            Mat::zeros(4, 4),
            crate::gw::Geometry::Grid3d {
                grid: crate::grid::Grid3d { n: 2, h: f64::NAN },
                k: 1,
            },
            uniform(4),
            uniform(8),
            0.01,
        );
        assert!(nan_h.validate().is_err());
        let tiny = JobPayload::gw_mixed(
            Mat::zeros(4, 4),
            crate::gw::Geometry::Grid1d {
                grid: crate::grid::Grid1d { n: 1, h: 1.0 },
                k: 1,
            },
            uniform(4),
            uniform(1),
            0.01,
        );
        assert!(tiny.validate().is_err());
    }

    #[test]
    fn mixed_fingerprint_tracks_dense_content() {
        let a = Mat::from_fn(4, 4, |i, j| (i + 3 * j) as f64 * 0.25);
        let b = a.map(|x| x + 1e-12);
        assert_eq!(mixed_fingerprint(&a), mixed_fingerprint(&a.clone()));
        assert_ne!(mixed_fingerprint(&a), mixed_fingerprint(&b));
        // The constructor stamps the same hash, independent of the
        // grid side (which is compared by descriptor, not hashed).
        let payload = JobPayload::gw_mixed(
            a.clone(),
            crate::gw::Geometry::grid_3d_unit(2, 1),
            uniform(4),
            uniform(8),
            0.01,
        );
        match payload {
            JobPayload::GwMixed { fingerprint, .. } => {
                assert_eq!(fingerprint, mixed_fingerprint(&a))
            }
            _ => unreachable!(),
        }
    }

    #[test]
    fn validate_screen_jobs() {
        let cloud = |seed: u64, n: usize| {
            let mut rng = crate::prng::Rng::seeded(seed);
            Mat::from_fn(n, 2, |_, _| rng.uniform())
        };
        let good = JobPayload::gw_screen(
            cloud(1, 6),
            vec![cloud(2, 5), cloud(3, 7)],
            1,
            0,
            false,
            0.05,
        );
        assert!(good.validate().is_ok(), "{:?}", good.validate());
        assert_eq!(good.points(), 6);
        assert_eq!(good.target_points(), 7);
        assert!(!good.is_structured());
        assert_eq!(good.epsilon(), 0.05);
        // top_k out of range.
        let bad_k =
            JobPayload::gw_screen(cloud(1, 6), vec![cloud(2, 5)], 2, 0, false, 0.05);
        assert!(bad_k.validate().is_err());
        // No candidates.
        let empty = JobPayload::gw_screen(cloud(1, 6), vec![], 1, 0, false, 0.05);
        assert!(empty.validate().is_err());
        // Dimension mismatch.
        let mut rng = crate::prng::Rng::seeded(9);
        let cand3 = Mat::from_fn(5, 3, |_, _| rng.uniform());
        let bad_dim = JobPayload::gw_screen(cloud(1, 6), vec![cand3], 1, 0, false, 0.05);
        assert!(bad_dim.validate().is_err());
        // Non-finite coordinates.
        let mut nan = cloud(4, 5);
        nan[(0, 0)] = f64::NAN;
        let bad_entries =
            JobPayload::gw_screen(cloud(1, 6), vec![nan], 1, 0, false, 0.05);
        assert!(bad_entries.validate().is_err());
    }

    #[test]
    fn screen_fingerprint_tracks_every_cloud_and_the_split() {
        let a = Mat::from_fn(4, 2, |i, j| (i + 2 * j) as f64 * 0.5);
        let b = a.map(|x| x + 1e-12);
        let fp = screen_fingerprint;
        assert_eq!(fp(&a, &[b.clone()]), fp(&a.clone(), &[b.clone()]));
        assert_ne!(fp(&a, &[a.clone()]), fp(&b, &[a.clone()]), "query folds");
        assert_ne!(fp(&a, &[a.clone()]), fp(&a, &[b.clone()]), "candidates fold");
        // Candidate count participates: [a, b] vs [a] must differ even
        // though the folded prefix agrees.
        assert_ne!(fp(&a, &[a.clone(), b.clone()]), fp(&a, &[a.clone()]));
        // The constructor stamps the same hash.
        match JobPayload::gw_screen(a.clone(), vec![b.clone()], 1, 0, false, 0.05) {
            JobPayload::GwScreen { fingerprint, .. } => {
                assert_eq!(fingerprint, fp(&a, &[b]))
            }
            _ => unreachable!(),
        }
    }

    #[test]
    fn backend_choice_round_trips_kinds() {
        for kind in [GradientKind::Fgc, GradientKind::Naive, GradientKind::LowRank] {
            assert_eq!(BackendChoice::native(kind).gradient_kind(), kind);
        }
        assert_eq!(
            BackendChoice::Pjrt("x".into()).gradient_kind(),
            GradientKind::Fgc
        );
    }

    #[test]
    fn every_payload_maps_into_the_family_label_set() {
        let families = crate::coordinator::LATENCY_FAMILIES;
        let d = Mat::zeros(4, 4);
        let payloads = [
            JobPayload::Gw1d {
                u: uniform(4),
                v: uniform(4),
                k: 1,
                epsilon: 0.01,
            },
            JobPayload::Fgw1d {
                u: uniform(4),
                v: uniform(4),
                feature_cost: Mat::zeros(4, 4),
                theta: 0.5,
                k: 1,
                epsilon: 0.01,
            },
            JobPayload::Gw2d {
                n: 2,
                u: uniform(4),
                v: uniform(4),
                k: 1,
                epsilon: 0.01,
            },
            JobPayload::Gw3d {
                n: 2,
                u: uniform(8),
                v: uniform(8),
                k: 1,
                epsilon: 0.01,
            },
            JobPayload::gw_dense(d.clone(), d.clone(), uniform(4), uniform(4), 0.01),
            JobPayload::gw_mixed(
                d.clone(),
                crate::gw::Geometry::grid_2d_unit(2, 1),
                uniform(4),
                uniform(4),
                0.01,
            ),
            JobPayload::gw_screen(Mat::zeros(4, 2), vec![Mat::zeros(4, 2)], 1, 0, false, 0.05),
        ];
        for p in &payloads {
            assert!(
                families.contains(&p.family()),
                "{} not in the exported label set",
                p.family()
            );
        }
        // FGW rides with its grid family; the coarse mixed family
        // collapses the structured-side dimension.
        assert_eq!(payloads[0].family(), "grid1d");
        assert_eq!(payloads[1].family(), "grid1d");
        assert_eq!(payloads[5].family(), "mixed");
        assert_eq!(payloads[6].family(), "screen");
    }

    #[test]
    fn validate_rejects_bad_2d_size() {
        let p = JobPayload::Gw2d {
            n: 3,
            u: uniform(8),
            v: uniform(9),
            k: 1,
            epsilon: 0.004,
        };
        assert!(p.validate().is_err());
    }
}
