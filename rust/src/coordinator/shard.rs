//! Variant-sharded job queue with pinned workers and work stealing.
//!
//! The pre-shard coordinator drained one shared [`super::BoundedQueue`]
//! and re-grouped each drain by [`VariantKey`], so a worker's warm
//! workspaces were only as good as the variant mix of its last drain.
//! [`ShardedQueue`] moves the grouping *into the queue layer*: jobs
//! hash by variant to a fixed shard, FIFO order holds within each
//! shard, and a worker stays **pinned** to one shard while it has work
//! — so consecutive pops are overwhelmingly same-variant and hit the
//! worker's warm workspace cache. When a worker's shard runs dry it
//! *steals* from the longest shard and re-pins there; after a bounded
//! streak of same-shard batches it *rotates* to the longest other
//! non-empty shard (the `rotate` flag on
//! [`ShardedQueue::pop_batch_pinned`]); and when its pinned shard's
//! depth falls below `1/`[`PIN_SHED_FACTOR`] of the longest other
//! shard's it **sheds** the pin early and serves the deep shard
//! instead (depth-aware pin expiry) — so a skewed variant mix neither
//! idles the pool, starves the other shards' jobs, nor leaves a deep
//! shard waiting on workers pinned to trickles.
//!
//! Admission enforces two budgets:
//! * **per-shard capacity** — one hot variant cannot monopolize the
//!   queue memory of every other variant;
//! * **global budget** — the total number of queued jobs across all
//!   shards, the service's overall backpressure threshold.

use super::batcher::VariantKey;
use crate::error::{Error, Result};
use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

/// Stable FNV-1a shard assignment for a variant key. Deterministic
/// across processes (unlike `DefaultHasher`'s randomized SipHash), so
/// shard placement is reproducible in tests and across restarts.
pub fn shard_for(key: &VariantKey, shards: usize) -> usize {
    debug_assert!(shards > 0);
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    let mut eat = |bytes: &[u8]| {
        for &b in bytes {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    };
    eat(key.backend.as_bytes());
    eat(key.family.as_bytes());
    eat(&(key.points as u64).to_le_bytes());
    eat(&key.k.to_le_bytes());
    (h % shards as u64) as usize
}

/// Depth ratio that expires a pin early: a worker whose pinned shard
/// still has work, but `PIN_SHED_FACTOR×` less of it than the longest
/// other shard, sheds the pin and serves the deep shard instead
/// (ROADMAP "cross-worker shard rebalancing"). 4 keeps warm-hit rates
/// high — mild imbalance never sheds — while bounding how long a deep
/// shard can wait on workers pinned to trickles.
pub const PIN_SHED_FACTOR: usize = 4;

/// One batch popped from the queue: all items come from a single
/// shard (FIFO), so they are overwhelmingly one variant.
#[derive(Debug)]
pub struct PoppedBatch<T> {
    /// Shard the items came from.
    pub shard: usize,
    /// True iff the worker left its pinned shard to take this batch.
    pub stolen: bool,
    /// True iff this steal was a depth-aware pin shed: the pinned
    /// shard still had work, but [`PIN_SHED_FACTOR`]× less than the
    /// shard served instead. Always implies `stolen`.
    pub shed: bool,
    /// The items, in shard-FIFO order.
    pub items: Vec<T>,
}

struct State<T> {
    shards: Vec<VecDeque<T>>,
    total: usize,
    closed: bool,
}

struct Inner<T> {
    state: Mutex<State<T>>,
    not_empty: Condvar,
    not_full: Condvar,
    shard_capacity: usize,
    global_budget: usize,
}

/// A bounded, variant-sharded MPMC queue (Mutex + Condvar; the offline
/// crate set has no crossbeam/tokio).
///
/// * `try_push` rejects immediately when the target shard or the
///   global budget is full (fail-fast admission).
/// * `push_timeout` blocks up to a deadline (backpressure).
/// * `pop_batch_pinned` blocks until work exists anywhere, prefers the
///   caller's pinned shard, steals from the longest shard otherwise,
///   and returns `None` once closed and fully drained.
pub struct ShardedQueue<T> {
    inner: Arc<Inner<T>>,
}

impl<T> Clone for ShardedQueue<T> {
    fn clone(&self) -> Self {
        ShardedQueue {
            inner: Arc::clone(&self.inner),
        }
    }
}

impl<T> ShardedQueue<T> {
    /// Create with `shards` shards, each holding at most
    /// `shard_capacity` items, with at most `global_budget` items
    /// queued in total. All three must be positive.
    pub fn new(shards: usize, shard_capacity: usize, global_budget: usize) -> Self {
        assert!(shards > 0, "shard count must be positive");
        assert!(shard_capacity > 0, "shard capacity must be positive");
        assert!(global_budget > 0, "global budget must be positive");
        ShardedQueue {
            inner: Arc::new(Inner {
                state: Mutex::new(State {
                    shards: (0..shards).map(|_| VecDeque::new()).collect(),
                    total: 0,
                    closed: false,
                }),
                not_empty: Condvar::new(),
                not_full: Condvar::new(),
                shard_capacity,
                global_budget,
            }),
        }
    }

    /// Number of shards.
    pub fn shards(&self) -> usize {
        self.inner.state.lock().unwrap().shards.len()
    }

    /// Total items queued across all shards.
    pub fn len(&self) -> usize {
        self.inner.state.lock().unwrap().total
    }

    /// True iff no items are queued.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Current depth of every shard (metrics surface).
    pub fn depths(&self) -> Vec<usize> {
        let st = self.inner.state.lock().unwrap();
        st.shards.iter().map(|q| q.len()).collect()
    }

    fn admission_full(&self, st: &State<T>, shard: usize) -> Option<Error> {
        if st.shards[shard].len() >= self.inner.shard_capacity {
            return Some(Error::Rejected(format!(
                "shard {shard} full (per-shard capacity {})",
                self.inner.shard_capacity
            )));
        }
        if st.total >= self.inner.global_budget {
            return Some(Error::Rejected(format!(
                "admission budget exhausted (global capacity {})",
                self.inner.global_budget
            )));
        }
        None
    }

    /// Non-blocking push to `shard`; `Err(Rejected)` when that shard
    /// or the global budget is full, or the queue is closed.
    pub fn try_push(&self, shard: usize, item: T) -> Result<()> {
        let mut st = self.inner.state.lock().unwrap();
        assert!(shard < st.shards.len(), "shard index out of range");
        if st.closed {
            return Err(Error::Rejected("queue closed".into()));
        }
        if let Some(e) = self.admission_full(&st, shard) {
            return Err(e);
        }
        st.shards[shard].push_back(item);
        st.total += 1;
        drop(st);
        self.inner.not_empty.notify_one();
        Ok(())
    }

    /// Blocking push with a deadline — the backpressure path.
    pub fn push_timeout(&self, shard: usize, item: T, timeout: Duration) -> Result<()> {
        let mut st = self.inner.state.lock().unwrap();
        assert!(shard < st.shards.len(), "shard index out of range");
        let deadline = std::time::Instant::now() + timeout;
        loop {
            if st.closed {
                return Err(Error::Rejected("queue closed".into()));
            }
            match self.admission_full(&st, shard) {
                None => {
                    st.shards[shard].push_back(item);
                    st.total += 1;
                    drop(st);
                    self.inner.not_empty.notify_one();
                    return Ok(());
                }
                Some(e) => {
                    let now = std::time::Instant::now();
                    if now >= deadline {
                        return Err(Error::Rejected(format!("backpressure timeout: {e}")));
                    }
                    let (guard, res) = self
                        .inner
                        .not_full
                        .wait_timeout(st, deadline - now)
                        .unwrap();
                    st = guard;
                    if res.timed_out() && self.admission_full(&st, shard).is_some() {
                        return Err(Error::Rejected("backpressure timeout".into()));
                    }
                }
            }
        }
    }

    /// Pop up to `max` items from one shard, preferring `*pinned`.
    ///
    /// Blocks until any shard has work. If the pinned shard has items
    /// it is drained first (the warm path) — unless its depth has
    /// fallen below `1/`[`PIN_SHED_FACTOR`] of the longest other
    /// shard's, in which case the pin is **shed** early and the deep
    /// shard is served instead (`stolen = true`, `shed = true`).
    /// Otherwise the **longest** shard is chosen (work stealing,
    /// `stolen = true`) and the worker re-pins there. `rotate = true`
    /// asks for a **fairness rotation**: take the longest *other*
    /// non-empty shard even though the pinned shard still has work
    /// (falling back to the pinned shard when no other has any) —
    /// callers rotate after a bounded streak of same-shard batches so
    /// a sustained hot variant cannot starve jobs queued in other
    /// shards. Returns `None` once the queue is closed and every shard
    /// is drained — the worker shutdown signal.
    pub fn pop_batch_pinned(
        &self,
        pinned: &mut Option<usize>,
        max: usize,
        rotate: bool,
    ) -> Option<PoppedBatch<T>> {
        let max = max.max(1);
        let mut st = self.inner.state.lock().unwrap();
        loop {
            if st.total > 0 {
                let longest_excluding = |st: &State<T>, skip: Option<usize>| {
                    st.shards
                        .iter()
                        .enumerate()
                        .filter(|(i, q)| Some(*i) != skip && !q.is_empty())
                        .max_by_key(|(i, q)| (q.len(), usize::MAX - i))
                        .map(|(i, _)| i)
                };
                let preferred = pinned.filter(|&p| p < st.shards.len() && !st.shards[p].is_empty());
                let (shard, stolen, shed) = match preferred {
                    Some(p) if !rotate => match longest_excluding(&st, Some(p)) {
                        // Depth-aware pin expiry: the pinned shard has
                        // only a trickle left while another runs deep —
                        // shed the pin and serve the deep shard.
                        Some(other)
                            if st.shards[p].len() * PIN_SHED_FACTOR
                                < st.shards[other].len() =>
                        {
                            (other, true, true)
                        }
                        _ => (p, false, false),
                    },
                    Some(p) => match longest_excluding(&st, Some(p)) {
                        // Fairness rotation: serve someone else's queue
                        // for one batch, then re-pin there.
                        Some(other) => (other, true, false),
                        None => (p, false, false),
                    },
                    None => {
                        let longest =
                            longest_excluding(&st, None).expect("total > 0 ⇒ a non-empty shard");
                        // Moving off a previously pinned (now dry)
                        // shard is a steal; a fresh worker just pins.
                        (longest, pinned.is_some_and(|p| p != longest), false)
                    }
                };
                let take = st.shards[shard].len().min(max);
                let items: Vec<T> = st.shards[shard].drain(..take).collect();
                st.total -= take;
                *pinned = Some(shard);
                drop(st);
                // Blocked producers wait on heterogeneous per-shard
                // predicates (their own shard's capacity + the global
                // budget), so a single `notify_one` could wake a
                // producer whose shard is still full and strand the
                // one whose shard just freed — wake them all.
                self.inner.not_full.notify_all();
                return Some(PoppedBatch {
                    shard,
                    stolen,
                    shed,
                    items,
                });
            }
            if st.closed {
                return None;
            }
            st = self.inner.not_empty.wait(st).unwrap();
        }
    }

    /// Take everything queued right now, across all shards, without
    /// blocking (shard order, FIFO within each shard). Used by
    /// fail-fast shutdown to turn still-queued envelopes into terminal
    /// results instead of silently dropping their channels.
    pub fn drain_all(&self) -> Vec<T> {
        let mut st = self.inner.state.lock().unwrap();
        let mut out = Vec::with_capacity(st.total);
        for shard in st.shards.iter_mut() {
            out.extend(shard.drain(..));
        }
        st.total = 0;
        drop(st);
        self.inner.not_full.notify_all();
        out
    }

    /// Close: producers start failing, consumers drain then get `None`.
    pub fn close(&self) {
        let mut st = self.inner.state.lock().unwrap();
        st.closed = true;
        drop(st);
        self.inner.not_empty.notify_all();
        self.inner.not_full.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    fn q(shards: usize, per_shard: usize, global: usize) -> ShardedQueue<u64> {
        ShardedQueue::new(shards, per_shard, global)
    }

    #[test]
    fn fifo_within_a_shard() {
        let sq = q(4, 8, 32);
        for i in 0..5 {
            sq.try_push(2, i).unwrap();
        }
        let mut pinned = Some(2);
        let batch = sq.pop_batch_pinned(&mut pinned, 3, false).unwrap();
        assert_eq!(batch.shard, 2);
        assert!(!batch.stolen);
        assert_eq!(batch.items, vec![0, 1, 2]);
        let batch = sq.pop_batch_pinned(&mut pinned, 8, false).unwrap();
        assert_eq!(batch.items, vec![3, 4]);
        assert!(!batch.stolen);
    }

    #[test]
    fn per_shard_capacity_rejects() {
        let sq = q(2, 2, 100);
        sq.try_push(0, 1).unwrap();
        sq.try_push(0, 2).unwrap();
        let err = sq.try_push(0, 3).unwrap_err();
        assert!(err.to_string().contains("shard 0 full"), "{err}");
        // The other shard still has room.
        sq.try_push(1, 4).unwrap();
        assert_eq!(sq.depths(), vec![2, 1]);
    }

    #[test]
    fn global_budget_rejects_even_with_shard_room() {
        let sq = q(4, 8, 3);
        sq.try_push(0, 1).unwrap();
        sq.try_push(1, 2).unwrap();
        sq.try_push(2, 3).unwrap();
        let err = sq.try_push(3, 4).unwrap_err();
        assert!(err.to_string().contains("admission budget"), "{err}");
        assert_eq!(sq.len(), 3);
    }

    #[test]
    fn steals_longest_shard_when_pinned_runs_dry() {
        let sq = q(3, 8, 32);
        sq.try_push(1, 10).unwrap();
        sq.try_push(2, 20).unwrap();
        sq.try_push(2, 21).unwrap();
        // Worker pinned to the empty shard 0 must steal from shard 2
        // (the longest) and re-pin there.
        let mut pinned = Some(0);
        let batch = sq.pop_batch_pinned(&mut pinned, 8, false).unwrap();
        assert_eq!(batch.shard, 2);
        assert!(batch.stolen);
        assert_eq!(batch.items, vec![20, 21]);
        assert_eq!(pinned, Some(2));
        // Next pop steals the remaining shard-1 item.
        let batch = sq.pop_batch_pinned(&mut pinned, 8, false).unwrap();
        assert_eq!(batch.shard, 1);
        assert!(batch.stolen);
        assert_eq!(batch.items, vec![10]);
    }

    #[test]
    fn rotation_serves_other_shards_under_sustained_load() {
        // The pinned shard never runs dry, but a rotating pop must
        // still serve the other shard's waiting job (anti-starvation).
        let sq = q(2, 8, 16);
        for i in 0..6 {
            sq.try_push(0, i).unwrap();
        }
        sq.try_push(1, 100).unwrap();
        let mut pinned = Some(0);
        // Non-rotating pops stay on the busy shard.
        let batch = sq.pop_batch_pinned(&mut pinned, 2, false).unwrap();
        assert_eq!((batch.shard, batch.stolen), (0, false));
        // A rotation takes the other non-empty shard and re-pins.
        let batch = sq.pop_batch_pinned(&mut pinned, 2, true).unwrap();
        assert_eq!((batch.shard, batch.stolen), (1, true));
        assert_eq!(batch.items, vec![100]);
        assert_eq!(pinned, Some(1));
        // Rotation with no *other* work falls back to the pinned shard.
        let mut pinned = Some(0);
        let batch = sq.pop_batch_pinned(&mut pinned, 8, true).unwrap();
        assert_eq!((batch.shard, batch.stolen), (0, false));
    }

    #[test]
    fn pop_wakes_every_blocked_producer() {
        // Producers block on *different* per-shard predicates; a pop
        // freeing shard 0 must wake the shard-0 producer even if the
        // shard-1 producer is also waiting (notify_all semantics —
        // notify_one could strand the right producer).
        let sq = q(2, 1, 4);
        sq.try_push(0, 10).unwrap();
        sq.try_push(1, 20).unwrap();
        let sq0 = sq.clone();
        let p0 = thread::spawn(move || sq0.push_timeout(0, 11, Duration::from_secs(10)));
        let sq1 = sq.clone();
        let p1 = thread::spawn(move || sq1.push_timeout(1, 21, Duration::from_secs(10)));
        thread::sleep(Duration::from_millis(50));
        // Free shard 0 only: its producer must complete promptly.
        let mut pinned = Some(0);
        let batch = sq.pop_batch_pinned(&mut pinned, 1, false).unwrap();
        assert_eq!(batch.items, vec![10]);
        p0.join().unwrap().unwrap();
        // Free shard 1: the other producer completes too.
        let mut pinned = Some(1);
        let batch = sq.pop_batch_pinned(&mut pinned, 1, false).unwrap();
        assert_eq!(batch.items, vec![20]);
        p1.join().unwrap().unwrap();
        assert_eq!(sq.depths(), vec![1, 1]);
    }

    #[test]
    fn depth_aware_pin_expiry_sheds_to_the_deep_shard() {
        let sq = q(3, 16, 64);
        sq.try_push(0, 1).unwrap();
        for i in 0..5 {
            sq.try_push(2, 20 + i).unwrap();
        }
        // Pinned depth 1 vs longest-other depth 5: 1·4 < 5 ⇒ shed.
        let mut pinned = Some(0);
        let batch = sq.pop_batch_pinned(&mut pinned, 8, false).unwrap();
        assert!(batch.stolen && batch.shed, "expected a shed steal");
        assert_eq!(batch.shard, 2);
        assert_eq!(batch.items, vec![20, 21, 22, 23, 24]);
        assert_eq!(pinned, Some(2), "shed re-pins on the deep shard");
        // The shallow shard's job is still there and served next.
        let batch = sq.pop_batch_pinned(&mut pinned, 8, false).unwrap();
        assert_eq!((batch.shard, batch.shed), (0, false));
        assert_eq!(batch.items, vec![1]);
    }

    #[test]
    fn mild_imbalance_keeps_the_pin() {
        let sq = q(2, 16, 64);
        for i in 0..2 {
            sq.try_push(0, i).unwrap();
        }
        for i in 0..8 {
            sq.try_push(1, 100 + i).unwrap();
        }
        // 2·4 < 8 is false (strict): the pin holds, no shed.
        let mut pinned = Some(0);
        let batch = sq.pop_batch_pinned(&mut pinned, 8, false).unwrap();
        assert_eq!((batch.shard, batch.stolen, batch.shed), (0, false, false));
        assert_eq!(batch.items, vec![0, 1]);
    }

    #[test]
    fn plain_steals_and_rotations_are_not_sheds() {
        let sq = q(2, 8, 16);
        sq.try_push(1, 5).unwrap();
        // Dry-pinned steal: stolen, not shed.
        let mut pinned = Some(0);
        let batch = sq.pop_batch_pinned(&mut pinned, 4, false).unwrap();
        assert!(batch.stolen && !batch.shed);
        // Rotation: stolen, not shed.
        for i in 0..3 {
            sq.try_push(0, i).unwrap();
        }
        sq.try_push(1, 6).unwrap();
        let mut pinned = Some(0);
        let batch = sq.pop_batch_pinned(&mut pinned, 4, true).unwrap();
        assert_eq!((batch.shard, batch.stolen, batch.shed), (1, true, false));
    }

    #[test]
    fn first_pop_is_a_pin_not_a_steal() {
        let sq = q(2, 8, 8);
        sq.try_push(1, 5).unwrap();
        let mut pinned = None;
        let batch = sq.pop_batch_pinned(&mut pinned, 4, false).unwrap();
        assert!(!batch.stolen);
        assert_eq!(pinned, Some(1));
    }

    #[test]
    fn close_drains_then_none() {
        let sq = q(2, 4, 8);
        sq.try_push(0, 7).unwrap();
        sq.close();
        assert!(sq.try_push(0, 8).is_err());
        let mut pinned = None;
        assert_eq!(sq.pop_batch_pinned(&mut pinned, 4, false).unwrap().items, vec![7]);
        assert!(sq.pop_batch_pinned(&mut pinned, 4, false).is_none());
    }

    #[test]
    fn drain_all_sweeps_every_shard() {
        let sq = q(3, 8, 32);
        sq.try_push(0, 1).unwrap();
        sq.try_push(2, 30).unwrap();
        sq.try_push(2, 31).unwrap();
        assert_eq!(sq.drain_all(), vec![1, 30, 31]);
        assert!(sq.is_empty());
        assert_eq!(sq.depths(), vec![0, 0, 0]);
        assert_eq!(sq.drain_all(), Vec::<u64>::new());
    }

    #[test]
    fn backpressure_releases_on_pop() {
        let sq = q(1, 1, 1);
        sq.try_push(0, 1).unwrap();
        let sq2 = sq.clone();
        let h = thread::spawn(move || sq2.push_timeout(0, 2, Duration::from_secs(5)));
        thread::sleep(Duration::from_millis(50));
        let mut pinned = None;
        assert_eq!(sq.pop_batch_pinned(&mut pinned, 1, false).unwrap().items, vec![1]);
        h.join().unwrap().unwrap();
        assert_eq!(sq.pop_batch_pinned(&mut pinned, 1, false).unwrap().items, vec![2]);
    }

    #[test]
    fn backpressure_times_out() {
        let sq = q(1, 1, 1);
        sq.try_push(0, 1).unwrap();
        let err = sq.push_timeout(0, 2, Duration::from_millis(30)).unwrap_err();
        assert!(err.to_string().contains("backpressure"), "{err}");
    }

    #[test]
    fn mpmc_under_contention_delivers_everything() {
        let sq = q(4, 4, 8);
        let producers: Vec<_> = (0..4)
            .map(|p| {
                let sq = sq.clone();
                thread::spawn(move || {
                    for i in 0..50u64 {
                        sq.push_timeout((p + i as usize) % 4, p as u64 * 1000 + i, Duration::from_secs(10))
                            .unwrap();
                    }
                })
            })
            .collect();
        let consumers: Vec<_> = (0..2)
            .map(|_| {
                let sq = sq.clone();
                thread::spawn(move || {
                    let mut got = 0usize;
                    let mut pinned = None;
                    while let Some(batch) = sq.pop_batch_pinned(&mut pinned, 8, false) {
                        got += batch.items.len();
                    }
                    got
                })
            })
            .collect();
        for p in producers {
            p.join().unwrap();
        }
        sq.close();
        let total: usize = consumers.into_iter().map(|c| c.join().unwrap()).sum();
        assert_eq!(total, 200);
    }

    #[test]
    fn shard_for_is_stable_and_in_range() {
        let key = VariantKey {
            backend: "native-fgc".into(),
            family: "gw1d",
            points: 128,
            k: 1,
        };
        for shards in [1usize, 2, 7, 16] {
            let s = shard_for(&key, shards);
            assert!(s < shards);
            assert_eq!(s, shard_for(&key, shards), "deterministic");
        }
        // Different variants spread (not all onto one shard).
        let spread: std::collections::BTreeSet<usize> = (0..64usize)
            .map(|n| {
                shard_for(
                    &VariantKey {
                        backend: "native-fgc".into(),
                        family: "gw1d",
                        points: n,
                        k: 1,
                    },
                    8,
                )
            })
            .collect();
        assert!(spread.len() > 2, "hash must spread variants: {spread:?}");
    }
}
