//! Bounded MPMC queue with backpressure (Mutex + Condvar; the offline
//! crate set has no crossbeam/tokio).
//!
//! Since the coordinator moved its native path onto the
//! variant-sharded [`super::ShardedQueue`], this single-lane queue
//! feeds only the dedicated PJRT worker (one consumer, artifact-shaped
//! jobs — sharding has nothing to pin there) and remains the generic
//! bounded-queue building block for tests and tools.

use crate::error::{Error, Result};
use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

struct Inner<T> {
    q: Mutex<State<T>>,
    not_empty: Condvar,
    not_full: Condvar,
    capacity: usize,
}

struct State<T> {
    items: VecDeque<T>,
    closed: bool,
}

/// A bounded multi-producer multi-consumer queue.
///
/// * `try_push` rejects immediately when full (the service's
///   fail-fast admission path).
/// * `push_timeout` blocks up to a deadline (backpressure).
/// * `pop` blocks until an item arrives or the queue is closed and
///   drained (then returns `None` — worker shutdown signal).
pub struct BoundedQueue<T> {
    inner: Arc<Inner<T>>,
}

impl<T> Clone for BoundedQueue<T> {
    fn clone(&self) -> Self {
        BoundedQueue {
            inner: Arc::clone(&self.inner),
        }
    }
}

impl<T> BoundedQueue<T> {
    /// Create with a positive capacity.
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0, "queue capacity must be positive");
        BoundedQueue {
            inner: Arc::new(Inner {
                q: Mutex::new(State {
                    items: VecDeque::new(),
                    closed: false,
                }),
                not_empty: Condvar::new(),
                not_full: Condvar::new(),
                capacity,
            }),
        }
    }

    /// Items currently queued.
    pub fn len(&self) -> usize {
        self.inner.q.lock().unwrap().items.len()
    }

    /// True iff currently empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Non-blocking push; `Err(Rejected)` when full or closed.
    pub fn try_push(&self, item: T) -> Result<()> {
        let mut st = self.inner.q.lock().unwrap();
        if st.closed {
            return Err(Error::Rejected("queue closed".into()));
        }
        if st.items.len() >= self.inner.capacity {
            return Err(Error::Rejected(format!(
                "queue full (capacity {})",
                self.inner.capacity
            )));
        }
        st.items.push_back(item);
        drop(st);
        self.inner.not_empty.notify_one();
        Ok(())
    }

    /// Blocking push with a deadline — the backpressure path.
    pub fn push_timeout(&self, item: T, timeout: Duration) -> Result<()> {
        let mut st = self.inner.q.lock().unwrap();
        let deadline = std::time::Instant::now() + timeout;
        loop {
            if st.closed {
                return Err(Error::Rejected("queue closed".into()));
            }
            if st.items.len() < self.inner.capacity {
                st.items.push_back(item);
                drop(st);
                self.inner.not_empty.notify_one();
                return Ok(());
            }
            let now = std::time::Instant::now();
            if now >= deadline {
                return Err(Error::Rejected("backpressure timeout".into()));
            }
            let (guard, res) = self
                .inner
                .not_full
                .wait_timeout(st, deadline - now)
                .unwrap();
            st = guard;
            if res.timed_out() && st.items.len() >= self.inner.capacity {
                return Err(Error::Rejected("backpressure timeout".into()));
            }
        }
    }

    /// Blocking pop; `None` once closed and drained.
    pub fn pop(&self) -> Option<T> {
        let mut st = self.inner.q.lock().unwrap();
        loop {
            if let Some(item) = st.items.pop_front() {
                drop(st);
                self.inner.not_full.notify_one();
                return Some(item);
            }
            if st.closed {
                return None;
            }
            st = self.inner.not_empty.wait(st).unwrap();
        }
    }

    /// Drain up to `max` items without blocking (batcher path).
    pub fn pop_batch(&self, max: usize) -> Vec<T> {
        let mut st = self.inner.q.lock().unwrap();
        let take = st.items.len().min(max);
        let out: Vec<T> = st.items.drain(..take).collect();
        drop(st);
        for _ in 0..out.len() {
            self.inner.not_full.notify_one();
        }
        out
    }

    /// Take everything queued right now without blocking. Used by
    /// fail-fast shutdown to turn still-queued envelopes into terminal
    /// results instead of silently dropping their channels.
    pub fn drain_all(&self) -> Vec<T> {
        let mut st = self.inner.q.lock().unwrap();
        let out: Vec<T> = st.items.drain(..).collect();
        drop(st);
        self.inner.not_full.notify_all();
        out
    }

    /// Close: producers start failing, consumers drain then get `None`.
    pub fn close(&self) {
        let mut st = self.inner.q.lock().unwrap();
        st.closed = true;
        drop(st);
        self.inner.not_empty.notify_all();
        self.inner.not_full.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn fifo_order() {
        let q = BoundedQueue::new(4);
        q.try_push(1).unwrap();
        q.try_push(2).unwrap();
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
    }

    #[test]
    fn rejects_when_full() {
        let q = BoundedQueue::new(2);
        q.try_push(1).unwrap();
        q.try_push(2).unwrap();
        assert!(q.try_push(3).is_err());
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn close_drains_then_none() {
        let q = BoundedQueue::new(4);
        q.try_push(7).unwrap();
        q.close();
        assert!(q.try_push(8).is_err());
        assert_eq!(q.pop(), Some(7));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn backpressure_releases_on_pop() {
        let q = BoundedQueue::new(1);
        q.try_push(1).unwrap();
        let q2 = q.clone();
        let h = thread::spawn(move || q2.push_timeout(2, Duration::from_secs(5)));
        thread::sleep(Duration::from_millis(50));
        assert_eq!(q.pop(), Some(1));
        h.join().unwrap().unwrap();
        assert_eq!(q.pop(), Some(2));
    }

    #[test]
    fn backpressure_times_out() {
        let q = BoundedQueue::new(1);
        q.try_push(1).unwrap();
        let err = q.push_timeout(2, Duration::from_millis(30)).unwrap_err();
        assert!(err.to_string().contains("backpressure"));
    }

    #[test]
    fn pop_batch_takes_up_to_max() {
        let q = BoundedQueue::new(10);
        for i in 0..5 {
            q.try_push(i).unwrap();
        }
        let batch = q.pop_batch(3);
        assert_eq!(batch, vec![0, 1, 2]);
        assert_eq!(q.len(), 2);
        assert_eq!(q.pop_batch(10), vec![3, 4]);
    }

    #[test]
    fn drain_all_empties_without_blocking() {
        let q = BoundedQueue::new(4);
        for i in 0..3 {
            q.try_push(i).unwrap();
        }
        assert_eq!(q.drain_all(), vec![0, 1, 2]);
        assert!(q.is_empty());
        assert_eq!(q.drain_all(), Vec::<i32>::new());
    }

    #[test]
    fn mpmc_under_contention() {
        let q = BoundedQueue::new(8);
        let producers: Vec<_> = (0..4)
            .map(|p| {
                let q = q.clone();
                thread::spawn(move || {
                    for i in 0..50 {
                        q.push_timeout(p * 1000 + i, Duration::from_secs(10)).unwrap();
                    }
                })
            })
            .collect();
        let consumers: Vec<_> = (0..2)
            .map(|_| {
                let q = q.clone();
                thread::spawn(move || {
                    let mut got = Vec::new();
                    while let Some(x) = q.pop() {
                        got.push(x);
                    }
                    got
                })
            })
            .collect();
        for p in producers {
            p.join().unwrap();
        }
        q.close();
        let total: usize = consumers.into_iter().map(|c| c.join().unwrap().len()).sum();
        assert_eq!(total, 200);
    }
}
