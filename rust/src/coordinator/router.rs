//! Backend routing: decide per job whether to run a native gradient
//! backend (auto-selected from the job's geometry) or a PJRT artifact.

use super::job::{BackendChoice, JobPayload};
use crate::gw::backend::auto_kind_for_sizes;
use crate::gw::GradientKind;
use crate::runtime::{ArtifactKind, ArtifactRegistry};

/// Routing policy knobs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RoutingPolicy {
    /// Prefer a matching PJRT artifact, else the auto-selected native
    /// backend (default).
    PreferPjrt,
    /// Always native, auto-selecting the gradient backend per job
    /// (grid → fgc, small dense → naive, large dense → lowrank).
    NativeOnly,
    /// Native dense baseline (for A/B benchmarking through the
    /// service path).
    BaselineOnly,
    /// Pin every job to one native gradient backend (`solver.backend`
    /// config key / `--backend` CLI flag).
    Force(GradientKind),
}

/// The router: artifact shape lookup + policy.
#[derive(Clone, Debug)]
pub struct Router {
    registry: ArtifactRegistry,
    policy: RoutingPolicy,
}

/// Auto-select the native backend from the payload's geometry — the
/// selection rule of `crate::gw::backend` (crossover constants in
/// `crate::gw::backend::cost_model`) applied at admission time. Grid
/// payloads (1D, 2D and 3D) and mixed dense×grid payloads are
/// fgc-exploitable — the separable engine scans any grid side — so
/// only fully dense payloads route by size.
fn native_auto(payload: &JobPayload) -> BackendChoice {
    let (m, n) = match payload {
        JobPayload::GwDense { dx, dy, .. } => (dx.rows(), dy.rows()),
        JobPayload::GwMixed { dx, grid, .. } => (dx.rows(), grid.len()),
        // Screening sizes by the exact escalation pairs it may run:
        // query vs the largest candidate (dense squared-Euclidean
        // geometries, so unstructured size-based selection applies).
        JobPayload::GwScreen {
            query, candidates, ..
        } => (
            query.rows(),
            candidates.iter().map(|c| c.rows()).max().unwrap_or(0),
        ),
        other => (other.points(), other.points()),
    };
    BackendChoice::native(auto_kind_for_sizes(payload.is_structured(), m, n))
}

impl Router {
    /// Build from a registry and policy.
    pub fn new(registry: ArtifactRegistry, policy: RoutingPolicy) -> Self {
        Router { registry, policy }
    }

    /// The active policy.
    pub fn policy(&self) -> RoutingPolicy {
        self.policy
    }

    /// Artifacts visible to this router.
    pub fn registry(&self) -> &ArtifactRegistry {
        &self.registry
    }

    /// Decide the backend for a payload.
    ///
    /// PJRT dispatch requires an exact `(kind, n)` artifact match
    /// *and* matching baked-in hyperparameters (ε, k) — otherwise the
    /// compiled solver would answer a different question; mismatches
    /// fall back to the native auto-selection, which takes runtime
    /// parameters.
    pub fn route(&self, payload: &JobPayload) -> BackendChoice {
        match self.policy {
            RoutingPolicy::NativeOnly => native_auto(payload),
            RoutingPolicy::BaselineOnly => BackendChoice::NativeNaive,
            RoutingPolicy::Force(kind) => BackendChoice::native(kind),
            RoutingPolicy::PreferPjrt => {
                let hit = match payload {
                    JobPayload::Gw1d { u, k, epsilon, .. } => self
                        .registry
                        .find(ArtifactKind::Gw1dSolve, u.len())
                        .filter(|s| s.k == *k && close(s.epsilon, *epsilon)),
                    JobPayload::Fgw1d { u, k, epsilon, .. } => self
                        .registry
                        .find(ArtifactKind::Fgw1dSolve, u.len())
                        .filter(|s| s.k == *k && close(s.epsilon, *epsilon)),
                    JobPayload::Gw2d { n, k, epsilon, .. } => self
                        .registry
                        .find(ArtifactKind::Gw2dSolve, *n)
                        .filter(|s| s.k == *k && close(s.epsilon, *epsilon)),
                    // No compiled artifact families exist for dense,
                    // mixed, 3D or screening jobs (yet).
                    JobPayload::Gw3d { .. }
                    | JobPayload::GwDense { .. }
                    | JobPayload::GwMixed { .. }
                    | JobPayload::GwScreen { .. } => None,
                };
                match hit {
                    Some(spec) => BackendChoice::Pjrt(spec.name.clone()),
                    None => native_auto(payload),
                }
            }
        }
    }
}

fn close(a: f64, b: f64) -> bool {
    (a - b).abs() <= 1e-12 + 1e-6 * a.abs().max(b.abs())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gw::backend::DENSE_LOWRANK_CROSSOVER;
    use crate::linalg::Mat;
    use std::path::Path;

    fn registry_with(n: usize) -> ArtifactRegistry {
        let dir = std::env::temp_dir().join(format!("fgcgw_router_{n}"));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.txt"),
            format!("gw1d_fgc_n{n} gw1d_solve {n} 1 0.002 10 100 2 gw1d_fgc_n{n}.hlo.txt\n"),
        )
        .unwrap();
        ArtifactRegistry::load(Path::new(&dir)).unwrap()
    }

    fn gw1d(n: usize, k: u32, eps: f64) -> JobPayload {
        JobPayload::Gw1d {
            u: vec![1.0 / n as f64; n],
            v: vec![1.0 / n as f64; n],
            k,
            epsilon: eps,
        }
    }

    fn dense(n: usize) -> JobPayload {
        JobPayload::gw_dense(
            Mat::zeros(n, n),
            Mat::zeros(n, n),
            vec![1.0 / n as f64; n],
            vec![1.0 / n as f64; n],
            0.01,
        )
    }

    #[test]
    fn prefers_pjrt_on_exact_match() {
        let r = Router::new(registry_with(64), RoutingPolicy::PreferPjrt);
        assert_eq!(
            r.route(&gw1d(64, 1, 0.002)),
            BackendChoice::Pjrt("gw1d_fgc_n64".into())
        );
    }

    #[test]
    fn falls_back_on_shape_or_param_mismatch() {
        let r = Router::new(registry_with(64), RoutingPolicy::PreferPjrt);
        assert_eq!(r.route(&gw1d(65, 1, 0.002)), BackendChoice::NativeFgc);
        assert_eq!(r.route(&gw1d(64, 2, 0.002)), BackendChoice::NativeFgc); // k mismatch
        assert_eq!(r.route(&gw1d(64, 1, 0.01)), BackendChoice::NativeFgc); // ε mismatch
    }

    #[test]
    fn dense_jobs_route_by_size() {
        for policy in [RoutingPolicy::PreferPjrt, RoutingPolicy::NativeOnly] {
            let r = Router::new(registry_with(64), policy);
            assert_eq!(
                r.route(&dense(DENSE_LOWRANK_CROSSOVER)),
                BackendChoice::NativeNaive
            );
            assert_eq!(
                r.route(&dense(DENSE_LOWRANK_CROSSOVER + 1)),
                BackendChoice::NativeLowRank
            );
        }
    }

    #[test]
    fn mixed_and_3d_jobs_route_fgc() {
        // A grid side of any dimension is fgc-exploitable regardless
        // of the dense side's size; 3D grid payloads likewise.
        let mixed = |m: usize| {
            JobPayload::gw_mixed(
                Mat::zeros(m, m),
                crate::gw::Geometry::grid_3d_unit(2, 1),
                vec![1.0 / m as f64; m],
                vec![1.0 / 8.0; 8],
                0.01,
            )
        };
        let gw3d = JobPayload::Gw3d {
            n: 2,
            u: vec![1.0 / 8.0; 8],
            v: vec![1.0 / 8.0; 8],
            k: 1,
            epsilon: 0.01,
        };
        for policy in [RoutingPolicy::PreferPjrt, RoutingPolicy::NativeOnly] {
            let r = Router::new(registry_with(64), policy);
            assert_eq!(r.route(&mixed(8)), BackendChoice::NativeFgc);
            assert_eq!(
                r.route(&mixed(DENSE_LOWRANK_CROSSOVER + 1)),
                BackendChoice::NativeFgc
            );
            assert_eq!(r.route(&gw3d), BackendChoice::NativeFgc);
        }
    }

    #[test]
    fn policies_override() {
        let r = Router::new(registry_with(64), RoutingPolicy::NativeOnly);
        assert_eq!(r.route(&gw1d(64, 1, 0.002)), BackendChoice::NativeFgc);
        let r = Router::new(registry_with(64), RoutingPolicy::BaselineOnly);
        assert_eq!(r.route(&gw1d(64, 1, 0.002)), BackendChoice::NativeNaive);
        let r = Router::new(
            registry_with(64),
            RoutingPolicy::Force(GradientKind::LowRank),
        );
        assert_eq!(r.route(&gw1d(64, 1, 0.002)), BackendChoice::NativeLowRank);
        assert_eq!(r.route(&dense(8)), BackendChoice::NativeLowRank);
    }
}
