//! Service metrics: counters + latency histogram (lock-free counters,
//! a mutex-guarded reservoir for percentiles). Completions are counted
//! per [`BackendChoice`] so backend auto-selection is observable in
//! production.

use super::job::BackendChoice;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// Live metrics shared across the service threads.
#[derive(Debug, Default)]
pub struct ServiceMetrics {
    submitted: AtomicU64,
    rejected: AtomicU64,
    completed: AtomicU64,
    failed: AtomicU64,
    native_fgc: AtomicU64,
    native_naive: AtomicU64,
    native_lowrank: AtomicU64,
    pjrt: AtomicU64,
    /// Jobs served by an already-warm worker workspace (no operator
    /// rebuild).
    warm_hits: AtomicU64,
    /// Jobs that forced a workspace build (cold variant or evicted).
    warm_misses: AtomicU64,
    /// Times a worker left its pinned shard to take another's work.
    steals: AtomicU64,
    /// Steals that were depth-aware pin sheds (the pinned shard still
    /// had work, but far less than the shard served instead). A
    /// subset of `steals`.
    sheds: AtomicU64,
    /// Worker panics caught by the isolation layer (`catch_unwind`).
    panics: AtomicU64,
    /// Worker solver-state respawns after a caught panic (the thread
    /// survives; its warm/executor state is rebuilt in place).
    respawns: AtomicU64,
    /// Degradation-ladder rung 1: forced log-domain regime retries.
    retries_regime: AtomicU64,
    /// Degradation-ladder rung 2: ε·2 annealed retries.
    retries_anneal: AtomicU64,
    /// Degradation-ladder rung 3: lowrank→naive backend fallbacks.
    retries_backend: AtomicU64,
    /// Jobs shed because their deadline could not be met (expired at
    /// admission/dequeue/mid-recovery, or admission under pressure).
    deadline_sheds: AtomicU64,
    /// Jobs quarantined after repeatedly panicking the worker.
    quarantines: AtomicU64,
    /// Fused batches split for blast-radius containment (members
    /// re-executed solo after a co-batched failure).
    batch_splits: AtomicU64,
    /// Jobs served on the f32 presolve + f64 refinement tier.
    f32_served: AtomicU64,
    /// Candidates scored by the sliced screening tier (one screen job
    /// contributes its whole candidate set).
    screened: AtomicU64,
    /// Screened candidates escalated to exact entropic solves (the
    /// top-k survivors). `escalated / screened` is the tier's
    /// work-avoidance ratio.
    escalated: AtomicU64,
    /// Live warm-cache occupancy across all workers, in capacity
    /// units (an f64-tier workspace charges 2 units, an f32-tier one
    /// 1 — its resident hot state is roughly half the bytes), so the
    /// effective warm capacity gained by the f32 tier is observable.
    warm_units: AtomicU64,
    /// Results that could not be delivered (receiver dropped/missing).
    lost_results: AtomicU64,
    /// Completed-job latencies in microseconds (queue + solve).
    latencies_us: Mutex<Vec<u64>>,
    solve_us_total: AtomicU64,
    queue_us_total: AtomicU64,
}

impl ServiceMetrics {
    /// Fresh metrics.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record an admission.
    pub fn on_submit(&self) {
        self.submitted.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a rejection (validation, backpressure, shutdown).
    pub fn on_reject(&self) {
        self.rejected.fetch_add(1, Ordering::Relaxed);
    }

    /// Record warm-workspace accounting for one executed group:
    /// `hits` jobs ran on an already-built operator, `misses` forced
    /// a build.
    pub fn on_warm(&self, hits: u64, misses: u64) {
        if hits > 0 {
            self.warm_hits.fetch_add(hits, Ordering::Relaxed);
        }
        if misses > 0 {
            self.warm_misses.fetch_add(misses, Ordering::Relaxed);
        }
    }

    /// Record a work-steal (a worker moved off its pinned shard).
    pub fn on_steal(&self) {
        self.steals.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a depth-aware pin shed (counted *in addition to* the
    /// steal it implies — see [`crate::coordinator::PIN_SHED_FACTOR`]).
    pub fn on_shed(&self) {
        self.sheds.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a worker panic caught by the isolation layer.
    pub fn on_panic(&self) {
        self.panics.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a worker solver-state respawn after a caught panic.
    pub fn on_respawn(&self) {
        self.respawns.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a rung-1 retry (forced log-domain regime).
    pub fn on_retry_regime(&self) {
        self.retries_regime.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a rung-2 retry (ε·2 anneal).
    pub fn on_retry_anneal(&self) {
        self.retries_anneal.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a rung-3 retry (lowrank→naive backend fallback).
    pub fn on_retry_backend(&self) {
        self.retries_backend.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a deadline shed (job dropped or cut short because its
    /// deadline passed or could not be met under queue pressure).
    pub fn on_deadline_shed(&self) {
        self.deadline_sheds.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a job quarantine (gave up after repeated panics).
    pub fn on_quarantine(&self) {
        self.quarantines.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a fused-batch split for blast-radius containment.
    pub fn on_batch_split(&self) {
        self.batch_splits.fetch_add(1, Ordering::Relaxed);
    }

    /// Record `jobs` solves served on the f32+refine precision tier.
    pub fn on_f32_served(&self, jobs: u64) {
        self.f32_served.fetch_add(jobs, Ordering::Relaxed);
    }

    /// Record `candidates` scored by a sliced screening pass.
    pub fn on_screened(&self, candidates: u64) {
        self.screened.fetch_add(candidates, Ordering::Relaxed);
    }

    /// Record `hits` screened candidates escalated to exact solves.
    pub fn on_escalated(&self, hits: u64) {
        self.escalated.fetch_add(hits, Ordering::Relaxed);
    }

    /// A warm workspace entered some worker's cache (`units` capacity
    /// units: 2 for f64-tier, 1 for f32-tier).
    pub fn add_warm_units(&self, units: u64) {
        self.warm_units.fetch_add(units, Ordering::Relaxed);
    }

    /// A warm workspace was evicted or dropped from a worker's cache.
    pub fn sub_warm_units(&self, units: u64) {
        self.warm_units.fetch_sub(units, Ordering::Relaxed);
    }

    /// Record an undeliverable result (receiver dropped or missing).
    pub fn on_lost_result(&self) {
        self.lost_results.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a completion for the backend that ran the job.
    pub fn on_complete(&self, backend: &BackendChoice, ok: bool, queue: Duration, solve: Duration) {
        if ok {
            self.completed.fetch_add(1, Ordering::Relaxed);
        } else {
            self.failed.fetch_add(1, Ordering::Relaxed);
        }
        match backend {
            BackendChoice::Pjrt(_) => self.pjrt.fetch_add(1, Ordering::Relaxed),
            BackendChoice::NativeFgc => self.native_fgc.fetch_add(1, Ordering::Relaxed),
            BackendChoice::NativeNaive => self.native_naive.fetch_add(1, Ordering::Relaxed),
            BackendChoice::NativeLowRank => self.native_lowrank.fetch_add(1, Ordering::Relaxed),
        };
        let total_us = (queue + solve).as_micros() as u64;
        self.queue_us_total
            .fetch_add(queue.as_micros() as u64, Ordering::Relaxed);
        self.solve_us_total
            .fetch_add(solve.as_micros() as u64, Ordering::Relaxed);
        self.latencies_us.lock().unwrap().push(total_us);
    }

    /// Snapshot for reporting.
    pub fn snapshot(&self) -> MetricsSnapshot {
        let mut lats = self.latencies_us.lock().unwrap().clone();
        lats.sort_unstable();
        let pct = |p: f64| -> Duration {
            if lats.is_empty() {
                return Duration::ZERO;
            }
            let idx = ((lats.len() as f64 - 1.0) * p).round() as usize;
            Duration::from_micros(lats[idx])
        };
        MetricsSnapshot {
            submitted: self.submitted.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            failed: self.failed.load(Ordering::Relaxed),
            native_fgc: self.native_fgc.load(Ordering::Relaxed),
            native_naive: self.native_naive.load(Ordering::Relaxed),
            native_lowrank: self.native_lowrank.load(Ordering::Relaxed),
            pjrt: self.pjrt.load(Ordering::Relaxed),
            warm_hits: self.warm_hits.load(Ordering::Relaxed),
            warm_misses: self.warm_misses.load(Ordering::Relaxed),
            steals: self.steals.load(Ordering::Relaxed),
            sheds: self.sheds.load(Ordering::Relaxed),
            panics: self.panics.load(Ordering::Relaxed),
            respawns: self.respawns.load(Ordering::Relaxed),
            retries_regime: self.retries_regime.load(Ordering::Relaxed),
            retries_anneal: self.retries_anneal.load(Ordering::Relaxed),
            retries_backend: self.retries_backend.load(Ordering::Relaxed),
            deadline_sheds: self.deadline_sheds.load(Ordering::Relaxed),
            quarantines: self.quarantines.load(Ordering::Relaxed),
            batch_splits: self.batch_splits.load(Ordering::Relaxed),
            f32_served: self.f32_served.load(Ordering::Relaxed),
            screened: self.screened.load(Ordering::Relaxed),
            escalated: self.escalated.load(Ordering::Relaxed),
            warm_units: self.warm_units.load(Ordering::Relaxed),
            lost_results: self.lost_results.load(Ordering::Relaxed),
            shard_depths: Vec::new(),
            p50: pct(0.50),
            p90: pct(0.90),
            p99: pct(0.99),
            mean_queue: Duration::from_micros(
                self.queue_us_total.load(Ordering::Relaxed)
                    / self.completed.load(Ordering::Relaxed).max(1),
            ),
            mean_solve: Duration::from_micros(
                self.solve_us_total.load(Ordering::Relaxed)
                    / self.completed.load(Ordering::Relaxed).max(1),
            ),
        }
    }
}

/// A point-in-time view of the service metrics.
#[derive(Clone, Debug)]
pub struct MetricsSnapshot {
    /// Jobs admitted.
    pub submitted: u64,
    /// Jobs rejected at admission.
    pub rejected: u64,
    /// Jobs completed successfully.
    pub completed: u64,
    /// Jobs that errored during solve.
    pub failed: u64,
    /// Completions per backend.
    pub native_fgc: u64,
    /// Dense-baseline completions.
    pub native_naive: u64,
    /// Low-rank backend completions.
    pub native_lowrank: u64,
    /// PJRT completions.
    pub pjrt: u64,
    /// Jobs served by an already-warm worker workspace.
    pub warm_hits: u64,
    /// Jobs that forced a workspace build.
    pub warm_misses: u64,
    /// Work-steal events across the worker pool.
    pub steals: u64,
    /// Depth-aware pin sheds (a subset of `steals`: the pinned shard
    /// still had work but far less than the shard served instead).
    pub sheds: u64,
    /// Worker panics caught by the isolation layer.
    pub panics: u64,
    /// Worker solver-state respawns after caught panics.
    pub respawns: u64,
    /// Rung-1 retries: forced log-domain regime.
    pub retries_regime: u64,
    /// Rung-2 retries: ε·2 anneal.
    pub retries_anneal: u64,
    /// Rung-3 retries: lowrank→naive backend fallback.
    pub retries_backend: u64,
    /// Jobs shed because their deadline passed or could not be met.
    pub deadline_sheds: u64,
    /// Jobs quarantined after repeatedly panicking the worker.
    pub quarantines: u64,
    /// Fused batches split for blast-radius containment.
    pub batch_splits: u64,
    /// Jobs served on the f32 presolve + f64 refinement tier.
    pub f32_served: u64,
    /// Candidates scored by the sliced screening tier.
    pub screened: u64,
    /// Screened candidates escalated to exact entropic solves.
    pub escalated: u64,
    /// Live warm-cache occupancy across all workers in capacity units
    /// (f64-tier workspace = 2, f32-tier = 1): the f32 tier's halved
    /// resident state shows up here as extra effective capacity.
    pub warm_units: u64,
    /// Results dropped because the receiver went away.
    pub lost_results: u64,
    /// Per-shard queue depth at snapshot time (filled by
    /// [`super::Coordinator::metrics`]; empty from a bare
    /// [`ServiceMetrics::snapshot`], which has no queue handle).
    pub shard_depths: Vec<usize>,
    /// Median end-to-end latency.
    pub p50: Duration,
    /// 90th percentile latency.
    pub p90: Duration,
    /// 99th percentile latency.
    pub p99: Duration,
    /// Mean queue wait.
    pub mean_queue: Duration,
    /// Mean solve time.
    pub mean_solve: Duration,
}

impl MetricsSnapshot {
    /// Fraction of executed jobs that hit an already-warm workspace
    /// (`NaN`-free: 0 when nothing has executed yet).
    pub fn warm_hit_rate(&self) -> f64 {
        let total = self.warm_hits + self.warm_misses;
        if total == 0 {
            0.0
        } else {
            self.warm_hits as f64 / total as f64
        }
    }
}

impl std::fmt::Display for MetricsSnapshot {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(
            f,
            "jobs: submitted={} rejected={} completed={} failed={}",
            self.submitted, self.rejected, self.completed, self.failed
        )?;
        writeln!(
            f,
            "backends: native-fgc={} native-naive={} native-lowrank={} pjrt={}",
            self.native_fgc, self.native_naive, self.native_lowrank, self.pjrt
        )?;
        writeln!(
            f,
            "sharding: warm-hits={} warm-misses={} (rate {:.1}%) steals={} sheds={} depths={:?}",
            self.warm_hits,
            self.warm_misses,
            100.0 * self.warm_hit_rate(),
            self.steals,
            self.sheds,
            self.shard_depths
        )?;
        writeln!(
            f,
            "faults: panics={} respawns={} retries=regime:{}/anneal:{}/backend:{} \
             deadline-sheds={} quarantines={} batch-splits={} lost-results={}",
            self.panics,
            self.respawns,
            self.retries_regime,
            self.retries_anneal,
            self.retries_backend,
            self.deadline_sheds,
            self.quarantines,
            self.batch_splits,
            self.lost_results
        )?;
        writeln!(
            f,
            "precision: f32-served={} warm-units={}",
            self.f32_served, self.warm_units
        )?;
        writeln!(
            f,
            "screening: screened={} escalated={}",
            self.screened, self.escalated
        )?;
        write!(
            f,
            "latency: p50={:.1?} p90={:.1?} p99={:.1?} (queue {:.1?} + solve {:.1?} mean)",
            self.p50, self.p90, self.p99, self.mean_queue, self.mean_solve
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_percentiles() {
        let m = ServiceMetrics::new();
        for i in 0..100u64 {
            m.on_submit();
            m.on_complete(
                &BackendChoice::NativeFgc,
                true,
                Duration::from_micros(10),
                Duration::from_micros(i * 10),
            );
        }
        m.on_reject();
        let s = m.snapshot();
        assert_eq!(s.submitted, 100);
        assert_eq!(s.rejected, 1);
        assert_eq!(s.completed, 100);
        assert_eq!(s.native_fgc, 100);
        assert!(s.p50 <= s.p90 && s.p90 <= s.p99);
        assert!(s.p50 >= Duration::from_micros(400) && s.p50 <= Duration::from_micros(600));
    }

    #[test]
    fn every_backend_choice_is_counted() {
        let m = ServiceMetrics::new();
        for (choice, times) in [
            (BackendChoice::NativeFgc, 1),
            (BackendChoice::NativeNaive, 2),
            (BackendChoice::NativeLowRank, 3),
            (BackendChoice::Pjrt("a".into()), 4),
        ] {
            for _ in 0..times {
                m.on_complete(&choice, true, Duration::ZERO, Duration::ZERO);
            }
        }
        let s = m.snapshot();
        assert_eq!(
            (s.native_fgc, s.native_naive, s.native_lowrank, s.pjrt),
            (1, 2, 3, 4)
        );
        assert_eq!(s.completed, 10);
        let text = s.to_string();
        assert!(text.contains("native-lowrank=3"));
    }

    #[test]
    fn empty_snapshot_is_zero() {
        let m = ServiceMetrics::new();
        let s = m.snapshot();
        assert_eq!(s.p99, Duration::ZERO);
        assert_eq!(s.completed, 0);
        assert_eq!(s.warm_hit_rate(), 0.0);
    }

    #[test]
    fn warm_and_steal_counters() {
        let m = ServiceMetrics::new();
        m.on_warm(7, 1);
        m.on_warm(2, 0);
        m.on_steal();
        m.on_steal();
        m.on_shed();
        let s = m.snapshot();
        assert_eq!(
            (s.warm_hits, s.warm_misses, s.steals, s.sheds),
            (9, 1, 2, 1)
        );
        assert!((s.warm_hit_rate() - 0.9).abs() < 1e-12);
        let text = s.to_string();
        assert!(text.contains("warm-hits=9"), "{text}");
        assert!(text.contains("steals=2"), "{text}");
        assert!(text.contains("sheds=1"), "{text}");
    }

    #[test]
    fn precision_counters_round_trip() {
        let m = ServiceMetrics::new();
        m.on_f32_served(3);
        m.add_warm_units(2);
        m.add_warm_units(1);
        m.sub_warm_units(2);
        let s = m.snapshot();
        assert_eq!((s.f32_served, s.warm_units), (3, 1));
        let text = s.to_string();
        assert!(text.contains("f32-served=3"), "{text}");
        assert!(text.contains("warm-units=1"), "{text}");
    }

    #[test]
    fn screening_counters_round_trip() {
        let m = ServiceMetrics::new();
        m.on_screened(64);
        m.on_screened(64);
        m.on_escalated(4);
        let s = m.snapshot();
        assert_eq!((s.screened, s.escalated), (128, 4));
        let text = s.to_string();
        assert!(text.contains("screened=128"), "{text}");
        assert!(text.contains("escalated=4"), "{text}");
    }

    #[test]
    fn fault_counters_round_trip() {
        let m = ServiceMetrics::new();
        m.on_panic();
        m.on_panic();
        m.on_respawn();
        m.on_retry_regime();
        m.on_retry_anneal();
        m.on_retry_backend();
        m.on_deadline_shed();
        m.on_deadline_shed();
        m.on_deadline_shed();
        m.on_quarantine();
        m.on_batch_split();
        m.on_lost_result();
        let s = m.snapshot();
        assert_eq!((s.panics, s.respawns), (2, 1));
        assert_eq!(
            (s.retries_regime, s.retries_anneal, s.retries_backend),
            (1, 1, 1)
        );
        assert_eq!(s.deadline_sheds, 3);
        assert_eq!((s.quarantines, s.batch_splits, s.lost_results), (1, 1, 1));
        let text = s.to_string();
        assert!(text.contains("panics=2"), "{text}");
        assert!(text.contains("deadline-sheds=3"), "{text}");
        assert!(text.contains("retries=regime:1/anneal:1/backend:1"), "{text}");
    }

    #[test]
    fn display_contains_fields() {
        let m = ServiceMetrics::new();
        m.on_submit();
        m.on_complete(
            &BackendChoice::Pjrt("x".into()),
            true,
            Duration::ZERO,
            Duration::from_millis(1),
        );
        let text = m.snapshot().to_string();
        assert!(text.contains("pjrt=1"));
        assert!(text.contains("p50"));
    }
}
