//! Service metrics: lock-free counters plus fixed-size log-bucketed
//! latency histograms (bounded memory no matter how many jobs run,
//! `O(LATENCY_BUCKETS)` percentile estimation — the `/metrics` scrape
//! path must be O(1) in traffic served). Completions are counted per
//! [`BackendChoice`] so backend auto-selection is observable in
//! production, and per variant family so tail latency can be read per
//! serving tier.

use super::job::BackendChoice;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Power-of-two latency buckets: bucket 0 holds 0µs completions,
/// bucket `i ≥ 1` holds `[2^(i−1), 2^i)` µs, and the last bucket
/// absorbs everything from `2^30` µs (~18 minutes) up. 32 buckets ×
/// one `u64` each bounds the whole histogram at a few hundred bytes —
/// the unbounded `Vec` reservoir this replaces grew 8 bytes per job
/// forever and was cloned + sorted `O(n log n)` on every snapshot.
pub const LATENCY_BUCKETS: usize = 32;

/// The fixed variant-family label set latency is bucketed under (one
/// label per serving tier — [`super::JobPayload::family`] maps every
/// payload into this set, so exported label cardinality cannot grow
/// with client traffic).
pub const LATENCY_FAMILIES: [&str; 6] =
    ["grid1d", "grid2d", "grid3d", "dense", "mixed", "screen"];

/// Bucket index for a latency of `us` microseconds.
fn bucket_index(us: u64) -> usize {
    if us == 0 {
        0
    } else {
        (64 - us.leading_zeros() as usize).min(LATENCY_BUCKETS - 1)
    }
}

/// Inclusive upper bound, in µs, of bucket `i` — the value percentile
/// estimation reports for ranks landing in that bucket. The last
/// bucket is conceptually unbounded; exporters should render it as
/// `+Inf`.
pub fn bucket_upper_us(i: usize) -> u64 {
    if i == 0 {
        0
    } else {
        (1u64 << i) - 1
    }
}

/// One lock-free latency histogram: fixed buckets, exact count/sum.
#[derive(Debug, Default)]
struct LatencyHistogram {
    buckets: [AtomicU64; LATENCY_BUCKETS],
    count: AtomicU64,
    sum_us: AtomicU64,
}

impl LatencyHistogram {
    /// Record one completion. Three relaxed `fetch_add`s — no lock,
    /// no allocation, bounded memory at any traffic volume.
    fn record(&self, us: u64) {
        self.buckets[bucket_index(us)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
    }

    fn snapshot(&self) -> LatencySnapshot {
        let mut buckets = [0u64; LATENCY_BUCKETS];
        for (out, b) in buckets.iter_mut().zip(&self.buckets) {
            *out = b.load(Ordering::Relaxed);
        }
        LatencySnapshot {
            buckets,
            count: self.count.load(Ordering::Relaxed),
            sum_us: self.sum_us.load(Ordering::Relaxed),
        }
    }
}

/// Point-in-time copy of one latency histogram.
#[derive(Clone, Debug, Default)]
pub struct LatencySnapshot {
    /// Completions per bucket (bucket `i` spans
    /// `(bucket_upper_us(i−1), bucket_upper_us(i)]`).
    pub buckets: [u64; LATENCY_BUCKETS],
    /// Total completions recorded.
    pub count: u64,
    /// Sum of recorded latencies in µs (tracked exactly, apart from
    /// the buckets, so the mean carries no bucketing error).
    pub sum_us: u64,
}

impl LatencySnapshot {
    /// Estimated `p`-quantile (`0 < p ≤ 1`): the upper bound of the
    /// bucket holding the rank-`⌈p·count⌉` completion. By
    /// construction the estimate is within one bucket width of the
    /// exact order statistic (never below it).
    pub fn percentile(&self, p: f64) -> Duration {
        if self.count == 0 {
            return Duration::ZERO;
        }
        let rank = ((self.count as f64) * p).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return Duration::from_micros(bucket_upper_us(i));
            }
        }
        Duration::from_micros(bucket_upper_us(LATENCY_BUCKETS - 1))
    }

    /// Exact mean of the recorded latencies (0 when empty).
    pub fn mean(&self) -> Duration {
        if self.count == 0 {
            Duration::ZERO
        } else {
            Duration::from_micros(self.sum_us / self.count)
        }
    }
}

/// Live metrics shared across the service threads.
#[derive(Debug, Default)]
pub struct ServiceMetrics {
    submitted: AtomicU64,
    rejected: AtomicU64,
    completed: AtomicU64,
    failed: AtomicU64,
    native_fgc: AtomicU64,
    native_naive: AtomicU64,
    native_lowrank: AtomicU64,
    pjrt: AtomicU64,
    /// Jobs served by an already-warm worker workspace (no operator
    /// rebuild).
    warm_hits: AtomicU64,
    /// Jobs that forced a workspace build (cold variant or evicted).
    warm_misses: AtomicU64,
    /// Times a worker left its pinned shard to take another's work.
    steals: AtomicU64,
    /// Steals that were depth-aware pin sheds (the pinned shard still
    /// had work, but far less than the shard served instead). A
    /// subset of `steals`.
    sheds: AtomicU64,
    /// Worker panics caught by the isolation layer (`catch_unwind`).
    panics: AtomicU64,
    /// Worker solver-state respawns after a caught panic (the thread
    /// survives; its warm/executor state is rebuilt in place).
    respawns: AtomicU64,
    /// Degradation-ladder rung 1: forced log-domain regime retries.
    retries_regime: AtomicU64,
    /// Degradation-ladder rung 2: ε·2 annealed retries.
    retries_anneal: AtomicU64,
    /// Degradation-ladder rung 3: lowrank→naive backend fallbacks.
    retries_backend: AtomicU64,
    /// Jobs shed because their deadline could not be met (expired at
    /// admission/dequeue/mid-recovery, or admission under pressure).
    deadline_sheds: AtomicU64,
    /// Jobs quarantined after repeatedly panicking the worker.
    quarantines: AtomicU64,
    /// Fused batches split for blast-radius containment (members
    /// re-executed solo after a co-batched failure).
    batch_splits: AtomicU64,
    /// Jobs served on the f32 presolve + f64 refinement tier.
    f32_served: AtomicU64,
    /// Candidates scored by the sliced screening tier (one screen job
    /// contributes its whole candidate set).
    screened: AtomicU64,
    /// Screened candidates escalated to exact entropic solves (the
    /// top-k survivors). `escalated / screened` is the tier's
    /// work-avoidance ratio.
    escalated: AtomicU64,
    /// Live warm-cache occupancy across all workers, in capacity
    /// units (an f64-tier workspace charges 2 units, an f32-tier one
    /// 1 — its resident hot state is roughly half the bytes), so the
    /// effective warm capacity gained by the f32 tier is observable.
    warm_units: AtomicU64,
    /// Results that could not be delivered (receiver dropped/missing).
    lost_results: AtomicU64,
    /// End-to-end (queue + solve) latency over all completions.
    latency: LatencyHistogram,
    /// End-to-end latency per variant family (indexed like
    /// [`LATENCY_FAMILIES`]).
    family_latency: [LatencyHistogram; LATENCY_FAMILIES.len()],
    solve_us_total: AtomicU64,
    queue_us_total: AtomicU64,
}

impl ServiceMetrics {
    /// Fresh metrics.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record an admission.
    pub fn on_submit(&self) {
        self.submitted.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a rejection (validation, backpressure, shutdown).
    pub fn on_reject(&self) {
        self.rejected.fetch_add(1, Ordering::Relaxed);
    }

    /// Record warm-workspace accounting for one executed group:
    /// `hits` jobs ran on an already-built operator, `misses` forced
    /// a build.
    pub fn on_warm(&self, hits: u64, misses: u64) {
        if hits > 0 {
            self.warm_hits.fetch_add(hits, Ordering::Relaxed);
        }
        if misses > 0 {
            self.warm_misses.fetch_add(misses, Ordering::Relaxed);
        }
    }

    /// Record a work-steal (a worker moved off its pinned shard).
    pub fn on_steal(&self) {
        self.steals.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a depth-aware pin shed (counted *in addition to* the
    /// steal it implies — see [`crate::coordinator::PIN_SHED_FACTOR`]).
    pub fn on_shed(&self) {
        self.sheds.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a worker panic caught by the isolation layer.
    pub fn on_panic(&self) {
        self.panics.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a worker solver-state respawn after a caught panic.
    pub fn on_respawn(&self) {
        self.respawns.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a rung-1 retry (forced log-domain regime).
    pub fn on_retry_regime(&self) {
        self.retries_regime.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a rung-2 retry (ε·2 anneal).
    pub fn on_retry_anneal(&self) {
        self.retries_anneal.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a rung-3 retry (lowrank→naive backend fallback).
    pub fn on_retry_backend(&self) {
        self.retries_backend.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a deadline shed (job dropped or cut short because its
    /// deadline passed or could not be met under queue pressure).
    pub fn on_deadline_shed(&self) {
        self.deadline_sheds.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a job quarantine (gave up after repeated panics).
    pub fn on_quarantine(&self) {
        self.quarantines.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a fused-batch split for blast-radius containment.
    pub fn on_batch_split(&self) {
        self.batch_splits.fetch_add(1, Ordering::Relaxed);
    }

    /// Record `jobs` solves served on the f32+refine precision tier.
    pub fn on_f32_served(&self, jobs: u64) {
        self.f32_served.fetch_add(jobs, Ordering::Relaxed);
    }

    /// Record `candidates` scored by a sliced screening pass.
    pub fn on_screened(&self, candidates: u64) {
        self.screened.fetch_add(candidates, Ordering::Relaxed);
    }

    /// Record `hits` screened candidates escalated to exact solves.
    pub fn on_escalated(&self, hits: u64) {
        self.escalated.fetch_add(hits, Ordering::Relaxed);
    }

    /// A warm workspace entered some worker's cache (`units` capacity
    /// units: 2 for f64-tier, 1 for f32-tier).
    pub fn add_warm_units(&self, units: u64) {
        self.warm_units.fetch_add(units, Ordering::Relaxed);
    }

    /// A warm workspace was evicted or dropped from a worker's cache.
    /// Saturating: a mismatched add/sub pairing clamps the gauge at 0
    /// instead of wrapping it to ~2⁶⁴ and poisoning every later
    /// export.
    pub fn sub_warm_units(&self, units: u64) {
        let _ = self
            .warm_units
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| {
                Some(v.saturating_sub(units))
            });
    }

    /// Record an undeliverable result (receiver dropped or missing).
    pub fn on_lost_result(&self) {
        self.lost_results.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a completion for the backend that ran the job and the
    /// variant family it belonged to
    /// ([`super::JobPayload::family`]; an unknown label still counts
    /// toward the global histogram).
    pub fn on_complete(
        &self,
        backend: &BackendChoice,
        family: &str,
        ok: bool,
        queue: Duration,
        solve: Duration,
    ) {
        if ok {
            self.completed.fetch_add(1, Ordering::Relaxed);
        } else {
            self.failed.fetch_add(1, Ordering::Relaxed);
        }
        match backend {
            BackendChoice::Pjrt(_) => self.pjrt.fetch_add(1, Ordering::Relaxed),
            BackendChoice::NativeFgc => self.native_fgc.fetch_add(1, Ordering::Relaxed),
            BackendChoice::NativeNaive => self.native_naive.fetch_add(1, Ordering::Relaxed),
            BackendChoice::NativeLowRank => self.native_lowrank.fetch_add(1, Ordering::Relaxed),
        };
        let total_us = (queue + solve).as_micros() as u64;
        self.queue_us_total
            .fetch_add(queue.as_micros() as u64, Ordering::Relaxed);
        self.solve_us_total
            .fetch_add(solve.as_micros() as u64, Ordering::Relaxed);
        self.latency.record(total_us);
        if let Some(i) = LATENCY_FAMILIES.iter().position(|f| *f == family) {
            self.family_latency[i].record(total_us);
        }
    }

    /// Snapshot for reporting.
    pub fn snapshot(&self) -> MetricsSnapshot {
        let latency = self.latency.snapshot();
        let completed = self.completed.load(Ordering::Relaxed);
        let failed = self.failed.load(Ordering::Relaxed);
        // Means divide by everything that ran — completions *and*
        // failures — because `on_complete` accumulates queue/solve
        // time for both (dividing by completions alone inflated the
        // means whenever jobs failed).
        let finished = (completed + failed).max(1);
        let mut family_latency: [LatencySnapshot; LATENCY_FAMILIES.len()] = Default::default();
        for (out, h) in family_latency.iter_mut().zip(&self.family_latency) {
            *out = h.snapshot();
        }
        MetricsSnapshot {
            submitted: self.submitted.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            completed,
            failed,
            native_fgc: self.native_fgc.load(Ordering::Relaxed),
            native_naive: self.native_naive.load(Ordering::Relaxed),
            native_lowrank: self.native_lowrank.load(Ordering::Relaxed),
            pjrt: self.pjrt.load(Ordering::Relaxed),
            warm_hits: self.warm_hits.load(Ordering::Relaxed),
            warm_misses: self.warm_misses.load(Ordering::Relaxed),
            steals: self.steals.load(Ordering::Relaxed),
            sheds: self.sheds.load(Ordering::Relaxed),
            panics: self.panics.load(Ordering::Relaxed),
            respawns: self.respawns.load(Ordering::Relaxed),
            retries_regime: self.retries_regime.load(Ordering::Relaxed),
            retries_anneal: self.retries_anneal.load(Ordering::Relaxed),
            retries_backend: self.retries_backend.load(Ordering::Relaxed),
            deadline_sheds: self.deadline_sheds.load(Ordering::Relaxed),
            quarantines: self.quarantines.load(Ordering::Relaxed),
            batch_splits: self.batch_splits.load(Ordering::Relaxed),
            f32_served: self.f32_served.load(Ordering::Relaxed),
            screened: self.screened.load(Ordering::Relaxed),
            escalated: self.escalated.load(Ordering::Relaxed),
            warm_units: self.warm_units.load(Ordering::Relaxed),
            lost_results: self.lost_results.load(Ordering::Relaxed),
            shard_depths: Vec::new(),
            p50: latency.percentile(0.50),
            p90: latency.percentile(0.90),
            p99: latency.percentile(0.99),
            mean_queue: Duration::from_micros(
                self.queue_us_total.load(Ordering::Relaxed) / finished,
            ),
            mean_solve: Duration::from_micros(
                self.solve_us_total.load(Ordering::Relaxed) / finished,
            ),
            latency,
            family_latency,
        }
    }
}

/// A point-in-time view of the service metrics.
#[derive(Clone, Debug)]
pub struct MetricsSnapshot {
    /// Jobs admitted.
    pub submitted: u64,
    /// Jobs rejected at admission.
    pub rejected: u64,
    /// Jobs completed successfully.
    pub completed: u64,
    /// Jobs that errored during solve.
    pub failed: u64,
    /// Completions per backend.
    pub native_fgc: u64,
    /// Dense-baseline completions.
    pub native_naive: u64,
    /// Low-rank backend completions.
    pub native_lowrank: u64,
    /// PJRT completions.
    pub pjrt: u64,
    /// Jobs served by an already-warm worker workspace.
    pub warm_hits: u64,
    /// Jobs that forced a workspace build.
    pub warm_misses: u64,
    /// Work-steal events across the worker pool.
    pub steals: u64,
    /// Depth-aware pin sheds (a subset of `steals`: the pinned shard
    /// still had work but far less than the shard served instead).
    pub sheds: u64,
    /// Worker panics caught by the isolation layer.
    pub panics: u64,
    /// Worker solver-state respawns after caught panics.
    pub respawns: u64,
    /// Rung-1 retries: forced log-domain regime.
    pub retries_regime: u64,
    /// Rung-2 retries: ε·2 anneal.
    pub retries_anneal: u64,
    /// Rung-3 retries: lowrank→naive backend fallback.
    pub retries_backend: u64,
    /// Jobs shed because their deadline passed or could not be met.
    pub deadline_sheds: u64,
    /// Jobs quarantined after repeatedly panicking the worker.
    pub quarantines: u64,
    /// Fused batches split for blast-radius containment.
    pub batch_splits: u64,
    /// Jobs served on the f32 presolve + f64 refinement tier.
    pub f32_served: u64,
    /// Candidates scored by the sliced screening tier.
    pub screened: u64,
    /// Screened candidates escalated to exact entropic solves.
    pub escalated: u64,
    /// Live warm-cache occupancy across all workers in capacity units
    /// (f64-tier workspace = 2, f32-tier = 1): the f32 tier's halved
    /// resident state shows up here as extra effective capacity.
    pub warm_units: u64,
    /// Results dropped because the receiver went away.
    pub lost_results: u64,
    /// Per-shard queue depth at snapshot time (filled by
    /// [`super::Coordinator::metrics`]; empty from a bare
    /// [`ServiceMetrics::snapshot`], which has no queue handle).
    pub shard_depths: Vec<usize>,
    /// End-to-end latency histogram over all completions.
    pub latency: LatencySnapshot,
    /// End-to-end latency histogram per variant family, indexed like
    /// [`LATENCY_FAMILIES`].
    pub family_latency: [LatencySnapshot; LATENCY_FAMILIES.len()],
    /// Median end-to-end latency (bucket upper bound — within one
    /// bucket width of exact).
    pub p50: Duration,
    /// 90th percentile latency.
    pub p90: Duration,
    /// 99th percentile latency.
    pub p99: Duration,
    /// Mean queue wait over finished (completed + failed) jobs.
    pub mean_queue: Duration,
    /// Mean solve time over finished (completed + failed) jobs.
    pub mean_solve: Duration,
}

impl MetricsSnapshot {
    /// Fraction of executed jobs that hit an already-warm workspace
    /// (`NaN`-free: 0 when nothing has executed yet).
    pub fn warm_hit_rate(&self) -> f64 {
        let total = self.warm_hits + self.warm_misses;
        if total == 0 {
            0.0
        } else {
            self.warm_hits as f64 / total as f64
        }
    }
}

impl std::fmt::Display for MetricsSnapshot {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(
            f,
            "jobs: submitted={} rejected={} completed={} failed={}",
            self.submitted, self.rejected, self.completed, self.failed
        )?;
        writeln!(
            f,
            "backends: native-fgc={} native-naive={} native-lowrank={} pjrt={}",
            self.native_fgc, self.native_naive, self.native_lowrank, self.pjrt
        )?;
        writeln!(
            f,
            "sharding: warm-hits={} warm-misses={} (rate {:.1}%) steals={} sheds={} depths={:?}",
            self.warm_hits,
            self.warm_misses,
            100.0 * self.warm_hit_rate(),
            self.steals,
            self.sheds,
            self.shard_depths
        )?;
        writeln!(
            f,
            "faults: panics={} respawns={} retries=regime:{}/anneal:{}/backend:{} \
             deadline-sheds={} quarantines={} batch-splits={} lost-results={}",
            self.panics,
            self.respawns,
            self.retries_regime,
            self.retries_anneal,
            self.retries_backend,
            self.deadline_sheds,
            self.quarantines,
            self.batch_splits,
            self.lost_results
        )?;
        writeln!(
            f,
            "precision: f32-served={} warm-units={}",
            self.f32_served, self.warm_units
        )?;
        writeln!(
            f,
            "screening: screened={} escalated={}",
            self.screened, self.escalated
        )?;
        write!(
            f,
            "latency: p50={:.1?} p90={:.1?} p99={:.1?} (queue {:.1?} + solve {:.1?} mean)",
            self.p50, self.p90, self.p99, self.mean_queue, self.mean_solve
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_percentiles() {
        let m = ServiceMetrics::new();
        for i in 0..100u64 {
            m.on_submit();
            m.on_complete(
                &BackendChoice::NativeFgc,
                "grid1d",
                true,
                Duration::from_micros(10),
                Duration::from_micros(i * 10),
            );
        }
        m.on_reject();
        let s = m.snapshot();
        assert_eq!(s.submitted, 100);
        assert_eq!(s.rejected, 1);
        assert_eq!(s.completed, 100);
        assert_eq!(s.native_fgc, 100);
        assert!(s.p50 <= s.p90 && s.p90 <= s.p99);
        assert!(s.p50 >= Duration::from_micros(400) && s.p50 <= Duration::from_micros(600));
    }

    #[test]
    fn every_backend_choice_is_counted() {
        let m = ServiceMetrics::new();
        for (choice, times) in [
            (BackendChoice::NativeFgc, 1),
            (BackendChoice::NativeNaive, 2),
            (BackendChoice::NativeLowRank, 3),
            (BackendChoice::Pjrt("a".into()), 4),
        ] {
            for _ in 0..times {
                m.on_complete(&choice, "grid1d", true, Duration::ZERO, Duration::ZERO);
            }
        }
        let s = m.snapshot();
        assert_eq!(
            (s.native_fgc, s.native_naive, s.native_lowrank, s.pjrt),
            (1, 2, 3, 4)
        );
        assert_eq!(s.completed, 10);
        let text = s.to_string();
        assert!(text.contains("native-lowrank=3"));
    }

    #[test]
    fn empty_snapshot_is_zero() {
        let m = ServiceMetrics::new();
        let s = m.snapshot();
        assert_eq!(s.p99, Duration::ZERO);
        assert_eq!(s.completed, 0);
        assert_eq!(s.warm_hit_rate(), 0.0);
        assert_eq!(s.latency.count, 0);
        assert_eq!(s.latency.mean(), Duration::ZERO);
    }

    #[test]
    fn warm_and_steal_counters() {
        let m = ServiceMetrics::new();
        m.on_warm(7, 1);
        m.on_warm(2, 0);
        m.on_steal();
        m.on_steal();
        m.on_shed();
        let s = m.snapshot();
        assert_eq!(
            (s.warm_hits, s.warm_misses, s.steals, s.sheds),
            (9, 1, 2, 1)
        );
        assert!((s.warm_hit_rate() - 0.9).abs() < 1e-12);
        let text = s.to_string();
        assert!(text.contains("warm-hits=9"), "{text}");
        assert!(text.contains("steals=2"), "{text}");
        assert!(text.contains("sheds=1"), "{text}");
    }

    #[test]
    fn precision_counters_round_trip() {
        let m = ServiceMetrics::new();
        m.on_f32_served(3);
        m.add_warm_units(2);
        m.add_warm_units(1);
        m.sub_warm_units(2);
        let s = m.snapshot();
        assert_eq!((s.f32_served, s.warm_units), (3, 1));
        let text = s.to_string();
        assert!(text.contains("f32-served=3"), "{text}");
        assert!(text.contains("warm-units=1"), "{text}");
    }

    #[test]
    fn warm_units_subtraction_saturates() {
        // A mismatched add/sub pairing must clamp the gauge at 0, not
        // wrap it to ~2⁶⁴.
        let m = ServiceMetrics::new();
        m.add_warm_units(1);
        m.sub_warm_units(5);
        assert_eq!(m.snapshot().warm_units, 0);
        // Still usable afterwards.
        m.add_warm_units(2);
        m.sub_warm_units(1);
        assert_eq!(m.snapshot().warm_units, 1);
    }

    #[test]
    fn screening_counters_round_trip() {
        let m = ServiceMetrics::new();
        m.on_screened(64);
        m.on_screened(64);
        m.on_escalated(4);
        let s = m.snapshot();
        assert_eq!((s.screened, s.escalated), (128, 4));
        let text = s.to_string();
        assert!(text.contains("screened=128"), "{text}");
        assert!(text.contains("escalated=4"), "{text}");
    }

    #[test]
    fn fault_counters_round_trip() {
        let m = ServiceMetrics::new();
        m.on_panic();
        m.on_panic();
        m.on_respawn();
        m.on_retry_regime();
        m.on_retry_anneal();
        m.on_retry_backend();
        m.on_deadline_shed();
        m.on_deadline_shed();
        m.on_deadline_shed();
        m.on_quarantine();
        m.on_batch_split();
        m.on_lost_result();
        let s = m.snapshot();
        assert_eq!((s.panics, s.respawns), (2, 1));
        assert_eq!(
            (s.retries_regime, s.retries_anneal, s.retries_backend),
            (1, 1, 1)
        );
        assert_eq!(s.deadline_sheds, 3);
        assert_eq!((s.quarantines, s.batch_splits, s.lost_results), (1, 1, 1));
        let text = s.to_string();
        assert!(text.contains("panics=2"), "{text}");
        assert!(text.contains("deadline-sheds=3"), "{text}");
        assert!(text.contains("retries=regime:1/anneal:1/backend:1"), "{text}");
    }

    #[test]
    fn display_contains_fields() {
        let m = ServiceMetrics::new();
        m.on_submit();
        m.on_complete(
            &BackendChoice::Pjrt("x".into()),
            "grid2d",
            true,
            Duration::ZERO,
            Duration::from_millis(1),
        );
        let text = m.snapshot().to_string();
        assert!(text.contains("pjrt=1"));
        assert!(text.contains("p50"));
    }

    #[test]
    fn means_divide_by_finished_not_completed() {
        // `on_complete` accumulates queue/solve time for failures too,
        // so the means must divide by completed + failed — dividing by
        // completions alone inflated them whenever jobs failed.
        let m = ServiceMetrics::new();
        m.on_complete(
            &BackendChoice::NativeFgc,
            "grid1d",
            true,
            Duration::from_micros(100),
            Duration::from_micros(100),
        );
        m.on_complete(
            &BackendChoice::NativeFgc,
            "grid1d",
            false,
            Duration::from_micros(300),
            Duration::from_micros(500),
        );
        let s = m.snapshot();
        assert_eq!((s.completed, s.failed), (1, 1));
        assert_eq!(s.mean_queue, Duration::from_micros(200));
        assert_eq!(s.mean_solve, Duration::from_micros(300));
    }

    #[test]
    fn percentiles_within_one_bucket_of_exact() {
        // For a power-of-two bucketed histogram the reported quantile
        // is the upper bound of the bucket holding the exact order
        // statistic: never below it, and less than 2× it (one bucket
        // width).
        let values: Vec<u64> = (1..=1000u64).map(|i| i * 7 + 3).collect();
        let m = ServiceMetrics::new();
        for &v in &values {
            m.on_complete(
                &BackendChoice::NativeFgc,
                "dense",
                true,
                Duration::ZERO,
                Duration::from_micros(v),
            );
        }
        let s = m.snapshot();
        let mut sorted = values.clone();
        sorted.sort_unstable();
        for (p, est) in [(0.50, s.p50), (0.90, s.p90), (0.99, s.p99)] {
            let rank = ((sorted.len() as f64) * p).ceil() as usize;
            let exact = sorted[rank - 1];
            let est_us = est.as_micros() as u64;
            assert!(est_us >= exact, "p{p}: estimate {est_us} below exact {exact}");
            assert!(
                est_us < 2 * exact,
                "p{p}: estimate {est_us} more than one bucket above exact {exact}"
            );
        }
        // The mean is exact (sum/count tracked apart from buckets).
        let exact_mean = sorted.iter().sum::<u64>() / sorted.len() as u64;
        assert_eq!(s.latency.mean(), Duration::from_micros(exact_mean));
    }

    #[test]
    fn family_histograms_split_by_family() {
        let m = ServiceMetrics::new();
        for (family, us) in [("grid1d", 10u64), ("grid1d", 20), ("screen", 4000)] {
            m.on_complete(
                &BackendChoice::NativeFgc,
                family,
                true,
                Duration::ZERO,
                Duration::from_micros(us),
            );
        }
        // An unknown family still lands in the global histogram.
        m.on_complete(
            &BackendChoice::NativeFgc,
            "mystery",
            true,
            Duration::ZERO,
            Duration::from_micros(1),
        );
        let s = m.snapshot();
        assert_eq!(s.latency.count, 4);
        let by_name = |name: &str| {
            let i = LATENCY_FAMILIES.iter().position(|f| *f == name).unwrap();
            &s.family_latency[i]
        };
        assert_eq!(by_name("grid1d").count, 2);
        assert_eq!(by_name("screen").count, 1);
        assert_eq!(by_name("dense").count, 0);
        assert_eq!(
            s.family_latency.iter().map(|h| h.count).sum::<u64>(),
            3,
            "the unknown family is global-only"
        );
    }

    #[test]
    fn bucket_bounds_cover_the_index_map() {
        assert_eq!(bucket_index(0), 0);
        assert_eq!(bucket_index(1), 1);
        assert_eq!(bucket_index(2), 2);
        assert_eq!(bucket_index(3), 2);
        assert_eq!(bucket_index(4), 3);
        assert_eq!(bucket_index(u64::MAX), LATENCY_BUCKETS - 1);
        for i in 0..LATENCY_BUCKETS - 1 {
            // Every bucket's upper bound maps back into that bucket,
            // and the next value starts the next bucket.
            assert_eq!(bucket_index(bucket_upper_us(i)), i);
            assert_eq!(bucket_index(bucket_upper_us(i) + 1), i + 1);
        }
    }
}
