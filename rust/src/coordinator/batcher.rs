//! Variant batching: group queued jobs that share a compiled-solver
//! variant so a worker runs them back-to-back (warm executable /
//! warm workspaces — the analogue of dynamic batching in serving
//! systems, adapted to CPU-bound solves with no batch dimension).

use super::job::{BackendChoice, JobPayload, JobRequest};
use crate::gw::{CouplingRank, Precision};

/// The grouping key: jobs with equal keys share workspaces and (for
/// PJRT) a compiled executable.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct VariantKey {
    /// Backend discriminator (PJRT name or native marker).
    pub backend: String,
    /// Problem family + size.
    pub family: &'static str,
    /// Support points.
    pub points: usize,
    /// Distance exponent.
    pub k: u32,
    /// Resolved solve-precision tier (admission stores the concrete
    /// tier in [`super::JobOptions`]; f32-tier and f64 jobs of the
    /// same shape must not share a lockstep batch or a warm
    /// workspace key).
    pub precision: Precision,
    /// Resolved coupling representation (admission stores the
    /// concrete choice): full-rank jobs run lockstep batches over an
    /// `M×N` workspace, factored jobs run the `O((M+N)·r)` coupling
    /// path — different workspaces, different variants, and the rank
    /// is part of the identity.
    pub coupling: CouplingRank,
}

/// Key for a request.
pub fn variant_key(req: &JobRequest) -> VariantKey {
    let backend = req.backend.to_string();
    let (family, points, k) = match &req.payload {
        JobPayload::Gw1d { u, k, .. } => ("gw1d", u.len(), *k),
        JobPayload::Fgw1d { u, k, .. } => ("fgw1d", u.len(), *k),
        JobPayload::Gw2d { n, k, .. } => ("gw2d", n * n, *k),
        JobPayload::Gw3d { n, k, .. } => ("gw3d", n * n * n, *k),
        // Dense jobs have no exponent; same-size dense jobs share
        // warm caches just fine.
        JobPayload::GwDense { u, .. } => ("gwdense", u.len(), 0),
        // Mixed jobs key on the dense (source) support size plus the
        // grid side's exponent; the geometry-identity sub-split in the
        // worker handles everything the key cannot.
        JobPayload::GwMixed { u, grid, .. } => {
            ("gwmixed", u.len(), grid.grid_exponent().unwrap_or(0))
        }
        // Screens key on query size plus candidate count (in `k`):
        // same-shape screens share the warm sliced workspace, which is
        // content-agnostic, so no finer identity is needed.
        JobPayload::GwScreen {
            query, candidates, ..
        } => ("gwscreen", query.rows(), candidates.len() as u32),
    };
    VariantKey {
        backend,
        family,
        points,
        k,
        precision: req.options.precision.unwrap_or(Precision::F64),
        coupling: req.options.coupling.unwrap_or(CouplingRank::Full),
    }
}

/// Stable-partition a drained batch by variant: runs of same-variant
/// jobs execute consecutively, preserving FIFO order *within* each
/// variant (fairness across variants is preserved at batch
/// granularity).
pub fn group_by_variant(mut jobs: Vec<JobRequest>) -> Vec<(VariantKey, Vec<JobRequest>)> {
    let mut groups: Vec<(VariantKey, Vec<JobRequest>)> = Vec::new();
    for job in jobs.drain(..) {
        let key = variant_key(&job);
        if let Some((_, bucket)) = groups.iter_mut().find(|(k, _)| *k == key) {
            bucket.push(job);
        } else {
            groups.push((key, vec![job]));
        }
    }
    groups
}

/// [`group_by_variant`] refined for *execution*: jobs also split on ε,
/// because a group runs as one lockstep batch through a single solver
/// configuration ([`crate::gw::EntropicGw::solve_batch_into`]) and ε
/// is a solver knob, not part of the variant. FIFO order within each
/// `(variant, ε)` group is preserved.
pub fn group_for_execution(mut jobs: Vec<JobRequest>) -> Vec<(VariantKey, f64, Vec<JobRequest>)> {
    let mut groups: Vec<(VariantKey, f64, Vec<JobRequest>)> = Vec::new();
    for job in jobs.drain(..) {
        let key = variant_key(&job);
        let eps = job.payload.epsilon();
        if let Some((_, _, bucket)) = groups
            .iter_mut()
            .find(|(k, e, _)| *k == key && e.to_bits() == eps.to_bits())
        {
            bucket.push(job);
        } else {
            groups.push((key, eps, vec![job]));
        }
    }
    groups
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Instant;

    fn req(id: u64, n: usize, backend: BackendChoice) -> JobRequest {
        JobRequest {
            id,
            payload: JobPayload::Gw1d {
                u: vec![1.0 / n as f64; n],
                v: vec![1.0 / n as f64; n],
                k: 1,
                epsilon: 0.002,
            },
            backend,
            submitted_at: Instant::now(),
            options: super::super::JobOptions::default(),
        }
    }

    #[test]
    fn groups_same_variant_preserving_order() {
        let jobs = vec![
            req(1, 8, BackendChoice::NativeFgc),
            req(2, 16, BackendChoice::NativeFgc),
            req(3, 8, BackendChoice::NativeFgc),
            req(4, 8, BackendChoice::NativeNaive),
        ];
        let groups = group_by_variant(jobs);
        assert_eq!(groups.len(), 3);
        let first = &groups[0];
        assert_eq!(first.0.points, 8);
        assert_eq!(
            first.1.iter().map(|j| j.id).collect::<Vec<_>>(),
            vec![1, 3]
        );
        assert_eq!(groups[1].1[0].id, 2);
        assert_eq!(groups[2].0.backend, "native-naive");
    }

    #[test]
    fn distinct_pjrt_artifacts_are_distinct_variants() {
        let jobs = vec![
            req(1, 8, BackendChoice::Pjrt("a".into())),
            req(2, 8, BackendChoice::Pjrt("b".into())),
        ];
        assert_eq!(group_by_variant(jobs).len(), 2);
    }

    #[test]
    fn execution_groups_split_on_epsilon() {
        let mut jobs = vec![
            req(1, 8, BackendChoice::NativeFgc),
            req(2, 8, BackendChoice::NativeFgc),
            req(3, 8, BackendChoice::NativeFgc),
        ];
        if let JobPayload::Gw1d { epsilon, .. } = &mut jobs[1].payload {
            *epsilon = 0.05;
        }
        let groups = group_for_execution(jobs);
        assert_eq!(groups.len(), 2);
        assert_eq!(
            groups[0].2.iter().map(|j| j.id).collect::<Vec<_>>(),
            vec![1, 3]
        );
        assert_eq!(groups[1].1, 0.05);
        assert_eq!(groups[1].2[0].id, 2);
    }
}
