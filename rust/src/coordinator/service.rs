//! The coordinator service: admission → routing → variant-sharded
//! queues → pinned warm workers → results + metrics.
//!
//! Native jobs hash by [`VariantKey`](super::VariantKey) to a shard of
//! a [`ShardedQueue`]; each worker pins to a shard while it has work and
//! owns a small LRU of warm [`GwBatchWorkspace`]s keyed by variant, so
//! a same-variant burst is executed as lockstep batches over one
//! already-built operator (zero rebuild — the warm-hit/steal counters
//! in [`MetricsSnapshot`] make the effect observable). When a worker's
//! shard runs dry it steals from the longest shard, so tail latency
//! does not regress under a skewed variant mix.
//!
//! Failures are a steady-state condition here, not an edge case
//! (entropic solvers are numerically fragile by construction), so the
//! execution path is fault-tolerant end to end: worker job execution
//! runs under `catch_unwind` (a panic respawns the worker's solver
//! state in place and quarantines a job that keeps panicking), numeric
//! failures climb a degradation ladder (forced log-domain regime →
//! ε·2 anneal → naive-backend fallback for dense payloads), a failed
//! member of a fused lockstep batch triggers a split so co-batched
//! neighbors are re-executed solo instead of inheriting the failure,
//! and per-job deadlines ([`JobOptions`]) are enforced at admission,
//! at dequeue, and between outer iterations of a recovery solve.
//! Every recovery path increments a [`MetricsSnapshot`] counter, and
//! the `fault-injection` feature adds deterministic hooks
//! ([`super::FaultScript`](crate::coordinator)) that script panics,
//! numeric failures, and regime mispredictions per job id.

use super::batcher::{group_for_execution, variant_key};
use super::job::{
    BackendChoice, JobId, JobOptions, JobPayload, JobRequest, JobResult, ScreenHit, ScreenOutcome,
};
use super::metrics::{MetricsSnapshot, ServiceMetrics};
use super::queue::BoundedQueue;
use super::router::{Router, RoutingPolicy};
use super::shard::{shard_for, ShardedQueue, PIN_SHED_FACTOR};
use crate::error::{Error, Result};
use crate::gw::backend::cost_model::{auto_coupling_for_sizes, screen_slices, SCREEN_SLICES_DEFAULT};
use crate::gw::{
    BatchJob, CouplingRank, EntropicGw, Geometry, GradientKind, GwBatchWorkspace, GwConfig,
    LowRankOptions, LrGwWorkspace, Precision, SlicedConfig, SlicedWorkspace,
};
use crate::linalg::Mat;
use crate::runtime::{ArtifactRegistry, Executor};
use crate::sinkhorn::Regime;
use std::collections::HashMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Per-worker warm-workspace LRU budget, in capacity **units**: an
/// f64-tier entry charges 2 units, an f32-tier entry 1 (its resident
/// hot state — kernel, plan, scan scratch — is roughly half the
/// bytes). Each entry holds a bound gradient operator plus per-job
/// solve buffers for one variant; 8 units (four f64 variants, up to
/// eight f32 ones) covers realistic mixes without unbounded memory
/// growth. The live occupancy is exported as `warm_units` in
/// [`MetricsSnapshot`].
const WARM_CACHE_UNITS: u64 = 8;

/// Consecutive same-shard batches a worker serves before it must
/// rotate to the longest *other* non-empty shard. Bounds cross-shard
/// wait under a sustained hot variant (a worker cannot starve other
/// shards for more than this many batches) while keeping the warm-hit
/// rate high — a rotation is at most one cold batch per streak.
const PIN_STREAK_MAX: usize = 4;

/// Service configuration.
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    /// Native compute threads.
    pub native_workers: usize,
    /// Variant shards in the native queue (`0` = auto: twice the
    /// worker count, capped at 16). Config key `coordinator.shards`,
    /// CLI `--shards`.
    pub shards: usize,
    /// Global admission budget of the native queue (jobs) — the
    /// overall backpressure threshold. Each shard additionally holds
    /// at most `ceil(queue_capacity / shards)` jobs, so one hot
    /// variant cannot exhaust the whole budget and starve admission
    /// for every other variant.
    pub queue_capacity: usize,
    /// Max jobs a worker drains from its shard per batch (also the
    /// lockstep batch ceiling).
    pub batch_max: usize,
    /// Artifact directory (`manifest.txt` inside).
    pub artifacts_dir: PathBuf,
    /// Routing policy.
    pub policy: RoutingPolicy,
    /// Spawn the PJRT worker (requires artifacts + libxla at runtime).
    pub enable_pjrt: bool,
    /// Mirror-descent outer iterations for native solves.
    pub outer_iters: usize,
    /// Inner Sinkhorn cap for native solves.
    pub sinkhorn_max_iters: usize,
    /// Inner Sinkhorn tolerance.
    pub sinkhorn_tolerance: f64,
    /// Per-job thread budget for the solver's hot kernels (`1` =
    /// serial; `0` = all cores — use with `native_workers = 1` to
    /// avoid oversubscription, the budgets multiply).
    pub solver_threads: usize,
    /// Low-rank factorization tolerance override (`0.0` = derive from
    /// each job's ε; see `LowRankOptions::for_epsilon`). Config key
    /// `solver.lowrank_tol`, CLI `--lowrank-tol`.
    pub lowrank_tol: f64,
    /// Default solve-precision tier for jobs that do not pick one
    /// ([`JobOptions::precision`] = `None`): `f64` (pure double),
    /// `f32` (f32 presolve + short f64 refinement), or `auto`
    /// (f32-tier at and above the cost model's size threshold).
    /// Config key `solver.precision`, CLI `--precision`.
    pub precision: Precision,
    /// Default coupling representation for pure-GW jobs that do not
    /// pick one ([`JobOptions::coupling`] = `None`): `None` = auto
    /// (factored `Γ = Q·diag(1/g)·Rᵀ` at and above the cost model's
    /// size threshold, rank from its memory budget),
    /// `Some(Full)` / `Some(LowRank(r))` forced. Config key
    /// `solver.coupling_rank`, CLI `--coupling-rank`.
    pub coupling: Option<CouplingRank>,
    /// How long `submit` may block under backpressure.
    pub submit_timeout: Duration,
    /// Default per-job deadline applied by [`Coordinator::submit`]
    /// (`None` = jobs never expire). Config key `service.deadline_ms`
    /// (`0` = none), CLI `--deadline-ms`.
    pub default_deadline: Option<Duration>,
    /// Default retry budget for the numeric degradation ladder
    /// (log-domain retry, ε·2 anneal, naive-backend fallback). Config
    /// key `service.max_retries`, CLI `--max-retries`.
    pub default_max_retries: u32,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            native_workers: 2,
            shards: 0,
            queue_capacity: 64,
            batch_max: 8,
            artifacts_dir: PathBuf::from("artifacts"),
            policy: RoutingPolicy::PreferPjrt,
            enable_pjrt: false,
            outer_iters: 10,
            sinkhorn_max_iters: 1000,
            sinkhorn_tolerance: 1e-9,
            solver_threads: 1,
            lowrank_tol: 0.0,
            precision: Precision::F64,
            coupling: None,
            submit_timeout: Duration::from_millis(200),
            default_deadline: None,
            default_max_retries: 3,
        }
    }
}

impl CoordinatorConfig {
    /// Resolve `shards = 0` to the auto default.
    fn effective_shards(&self) -> usize {
        if self.shards > 0 {
            self.shards
        } else {
            (self.native_workers.max(1) * 2).clamp(1, 16)
        }
    }
}

type Envelope = (JobRequest, mpsc::Sender<JobResult>);

/// Always-compiled handle to the optional fault-injection script.
/// Without the `fault-injection` feature this is an empty shell whose
/// probes compile to constants — the production path pays nothing.
#[derive(Clone, Default)]
struct Faults {
    #[cfg(feature = "fault-injection")]
    script: Option<Arc<super::fault::FaultScript>>,
}

impl Faults {
    /// Fire any scripted fault for one execution attempt of job `id`:
    /// panics in place (scripted panic arm) or returns the scripted
    /// numeric error. `Ok(())` when nothing is scripted.
    fn fire(&self, id: JobId) -> Result<()> {
        #[cfg(feature = "fault-injection")]
        if let Some(script) = &self.script {
            if script.take_panic(id) {
                panic!("injected panic (job {id})");
            }
            if script.take_numeric(id) {
                return Err(Error::Numeric(format!("injected numeric fault (job {id})")));
            }
        }
        let _ = id;
        Ok(())
    }

    /// True when this attempt of job `id` is scripted to run with a
    /// deliberately mispredicted (forced-Gibbs) Sinkhorn regime.
    fn mispredict(&self, id: JobId) -> bool {
        #[cfg(feature = "fault-injection")]
        if let Some(script) = &self.script {
            return script.take_mispredict(id);
        }
        let _ = id;
        false
    }
}

/// Everything a worker loop needs besides its queue.
struct WorkerCtx {
    metrics: Arc<ServiceMetrics>,
    cfg: CoordinatorConfig,
    draining: Arc<AtomicBool>,
    faults: Faults,
}

/// Running service handle.
pub struct Coordinator {
    cfg: CoordinatorConfig,
    router: Router,
    native_q: ShardedQueue<Envelope>,
    shard_count: usize,
    pjrt_q: Option<BoundedQueue<Envelope>>,
    metrics: Arc<ServiceMetrics>,
    workers: Vec<JoinHandle<()>>,
    next_id: AtomicU64,
    draining: Arc<AtomicBool>,
}

impl Coordinator {
    /// Load artifacts, spawn workers, return the handle.
    pub fn start(cfg: CoordinatorConfig) -> Result<Self> {
        Self::start_inner(cfg, Faults::default())
    }

    /// [`Coordinator::start`] with a deterministic fault script wired
    /// into every worker (feature `fault-injection`). Job ids are
    /// assigned sequentially from 1 in submission order, so a test can
    /// script faults for jobs it has not submitted yet.
    #[cfg(feature = "fault-injection")]
    pub fn start_with_faults(
        cfg: CoordinatorConfig,
        script: Arc<super::fault::FaultScript>,
    ) -> Result<Self> {
        Self::start_inner(
            cfg,
            Faults {
                script: Some(script),
            },
        )
    }

    fn start_inner(cfg: CoordinatorConfig, faults: Faults) -> Result<Self> {
        let registry = ArtifactRegistry::load(&cfg.artifacts_dir)?;
        let effective_policy = if cfg.enable_pjrt {
            cfg.policy
        } else {
            // Without a PJRT worker, artifact routes would strand jobs.
            match cfg.policy {
                RoutingPolicy::PreferPjrt => RoutingPolicy::NativeOnly,
                p => p,
            }
        };
        let router = Router::new(registry, effective_policy);
        let shard_count = cfg.effective_shards();
        let per_shard = cfg.queue_capacity.div_ceil(shard_count).max(1);
        let native_q: ShardedQueue<Envelope> =
            ShardedQueue::new(shard_count, per_shard, cfg.queue_capacity);
        let metrics = Arc::new(ServiceMetrics::new());
        let draining = Arc::new(AtomicBool::new(false));
        let mut workers = Vec::new();

        for wid in 0..cfg.native_workers.max(1) {
            let q = native_q.clone();
            let ctx = WorkerCtx {
                metrics: Arc::clone(&metrics),
                cfg: cfg.clone(),
                draining: Arc::clone(&draining),
                faults: faults.clone(),
            };
            workers.push(
                std::thread::Builder::new()
                    .name(format!("fgcgw-native-{wid}"))
                    .spawn(move || native_worker_loop(q, ctx))
                    .map_err(|e| Error::Runtime(format!("spawn worker: {e}")))?,
            );
        }

        let pjrt_q = if cfg.enable_pjrt {
            let q: BoundedQueue<Envelope> = BoundedQueue::new(cfg.queue_capacity);
            let q2 = q.clone();
            let ctx = WorkerCtx {
                metrics: Arc::clone(&metrics),
                cfg: cfg.clone(),
                draining: Arc::clone(&draining),
                faults: faults.clone(),
            };
            let registry2 = router.registry().clone();
            workers.push(
                std::thread::Builder::new()
                    .name("fgcgw-pjrt".into())
                    .spawn(move || pjrt_worker_loop(q2, ctx, registry2))
                    .map_err(|e| Error::Runtime(format!("spawn pjrt worker: {e}")))?,
            );
            Some(q)
        } else {
            None
        };

        Ok(Coordinator {
            cfg,
            router,
            native_q,
            shard_count,
            pjrt_q,
            metrics,
            workers,
            next_id: AtomicU64::new(1),
            draining,
        })
    }

    /// The router (inspection / tests).
    pub fn router(&self) -> &Router {
        &self.router
    }

    /// Shards in the native queue.
    pub fn shards(&self) -> usize {
        self.shard_count
    }

    /// Submit a job with the configured default [`JobOptions`];
    /// returns its id and the result channel. Rejects on invalid
    /// payloads and on backpressure timeout (per-shard or global
    /// admission budget).
    pub fn submit(&self, payload: JobPayload) -> Result<(JobId, mpsc::Receiver<JobResult>)> {
        self.submit_with_options(
            payload,
            JobOptions {
                deadline: self.cfg.default_deadline,
                max_retries: self.cfg.default_max_retries,
                precision: None,
                coupling: None,
            },
        )
    }

    /// [`Coordinator::submit`] with explicit per-job deadline/retry
    /// options. A deadline the service already knows it cannot meet is
    /// shed here at admission — deadline pressure maps onto the same
    /// [`PIN_SHED_FACTOR`] depth budget the workers' pin shed uses —
    /// rather than queueing the job past its expiry.
    pub fn submit_with_options(
        &self,
        payload: JobPayload,
        options: JobOptions,
    ) -> Result<(JobId, mpsc::Receiver<JobResult>)> {
        if let Err(msg) = payload.validate() {
            self.metrics.on_reject();
            return Err(Error::Rejected(format!("validation: {msg}")));
        }
        // Resolve the job's precision tier at admission: an explicit
        // per-job choice wins over the service default, and `Auto` is
        // resolved against the job's shape here — so the variant key,
        // the warm cache and the workers all see a concrete tier.
        let mut options = options;
        let (pm, pn) = payload_dims(&payload);
        options.precision = Some(
            options
                .precision
                .unwrap_or(self.cfg.precision)
                .resolve(pm, pn),
        );
        // Likewise the coupling representation: an explicit per-job
        // choice wins over the service default, and auto (no choice at
        // either level) resolves against the job's shape here. FGW
        // payloads always solve full-rank — the factored coupling is a
        // pure-GW path.
        // Screen jobs also pin full-rank: their escalated exact solves
        // run one query-vs-candidate pair at a time through full-rank
        // batch workspaces, and the screen itself holds no coupling.
        options.coupling = Some(if matches!(
            payload,
            JobPayload::Fgw1d { .. } | JobPayload::GwScreen { .. }
        ) {
            CouplingRank::Full
        } else {
            options
                .coupling
                .or(self.cfg.coupling)
                .unwrap_or_else(|| auto_coupling_for_sizes(pm, pn))
        });
        let backend = self.router.route(&payload);
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = mpsc::channel();
        let req = JobRequest {
            id,
            payload,
            backend: backend.clone(),
            submitted_at: Instant::now(),
            options,
        };
        let use_pjrt = matches!(&backend, BackendChoice::Pjrt(_)) && self.pjrt_q.is_some();
        let shard = if use_pjrt {
            0
        } else {
            shard_for(&variant_key(&req), self.shard_count)
        };
        if let Some(deadline) = options.deadline {
            let depth = if use_pjrt {
                self.pjrt_q.as_ref().map_or(0, |q| q.len())
            } else {
                self.native_q.depths()[shard]
            };
            let lane_deep = depth >= PIN_SHED_FACTOR * self.cfg.batch_max.max(1);
            if deadline.is_zero() || (lane_deep && deadline <= self.cfg.submit_timeout) {
                self.metrics.on_deadline_shed();
                self.metrics.on_reject();
                return Err(Error::Rejected(format!(
                    "deadline {deadline:?} cannot be met (lane depth {depth})"
                )));
            }
        }
        let pushed = match (&backend, &self.pjrt_q) {
            (BackendChoice::Pjrt(_), Some(q)) => q.push_timeout((req, tx), self.cfg.submit_timeout),
            _ => self
                .native_q
                .push_timeout(shard, (req, tx), self.cfg.submit_timeout),
        };
        match pushed {
            Ok(()) => {
                self.metrics.on_submit();
                Ok((id, rx))
            }
            Err(e) => {
                self.metrics.on_reject();
                Err(e)
            }
        }
    }

    /// Convenience: submit and wait for the result.
    pub fn submit_and_wait(&self, payload: JobPayload) -> Result<JobResult> {
        let (_, rx) = self.submit(payload)?;
        rx.recv()
            .map_err(|_| Error::Runtime("worker dropped result channel".into()))
    }

    /// Submit with `timeout` as the job's deadline and wait at most
    /// that long (plus the submit backpressure budget as grace for a
    /// solve already in flight when the deadline lapses). Unlike
    /// [`Coordinator::submit_and_wait`], this can never block forever:
    /// it returns the result — possibly a deadline-shed rejection — or
    /// gives up with [`Error::Rejected`].
    pub fn submit_and_wait_timeout(
        &self,
        payload: JobPayload,
        timeout: Duration,
    ) -> Result<JobResult> {
        let options = JobOptions {
            deadline: Some(timeout),
            max_retries: self.cfg.default_max_retries,
            precision: None,
            coupling: None,
        };
        let (_, rx) = self.submit_with_options(payload, options)?;
        let wait = timeout.saturating_add(self.cfg.submit_timeout);
        rx.recv_timeout(wait).map_err(|e| match e {
            mpsc::RecvTimeoutError::Timeout => {
                Error::Rejected(format!("no result within {wait:?}"))
            }
            mpsc::RecvTimeoutError::Disconnected => {
                Error::Runtime("worker dropped result channel".into())
            }
        })
    }

    /// Current metrics, including live per-shard queue depths.
    pub fn metrics(&self) -> MetricsSnapshot {
        let mut snap = self.metrics.snapshot();
        snap.shard_depths = self.native_q.depths();
        snap
    }

    /// Shared handle to the live metrics. The counters outlive the
    /// coordinator itself, so a serving front-end can print a final
    /// snapshot after [`Coordinator::shutdown`] has consumed the
    /// handle that owned the workers.
    pub fn metrics_handle(&self) -> Arc<ServiceMetrics> {
        Arc::clone(&self.metrics)
    }

    /// Graceful shutdown: close queues and let the workers solve
    /// everything already queued before joining them.
    pub fn shutdown(self) {
        self.finish(false)
    }

    /// Fail-fast shutdown: jobs still queued are drained to terminal
    /// [`Error::Rejected`] results instead of being solved, so no
    /// caller is ever left holding a dead channel. Solves already in
    /// flight still finish and deliver.
    pub fn shutdown_now(self) {
        self.finish(true)
    }

    fn finish(self, drain_fast: bool) {
        if drain_fast {
            self.draining.store(true, Ordering::SeqCst);
        }
        self.native_q.close();
        if let Some(q) = &self.pjrt_q {
            q.close();
        }
        for w in self.workers {
            let _ = w.join();
        }
        // Belt and braces: workers drain their queues before exiting,
        // so these sweeps are normally empty — but a result channel
        // must never die silently, whatever path led here.
        let mut leftovers = self.native_q.drain_all();
        if let Some(q) = &self.pjrt_q {
            leftovers.extend(q.drain_all());
        }
        for (req, tx) in leftovers {
            let result = rejected_result(&req, "coordinator shutting down");
            report(&self.metrics, &result);
            if tx.send(result).is_err() {
                self.metrics.on_lost_result();
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Workers
// ---------------------------------------------------------------------------

/// Warm-workspace identity: jobs agreeing on all of this share a
/// [`GwBatchWorkspace`] (for `dense`, geometry equality is verified
/// against the cached operator as well — the key alone cannot prove
/// two distance matrices equal).
#[derive(Clone, Debug, PartialEq)]
struct WsKey {
    family: &'static str,
    m: usize,
    n: usize,
    k: u32,
    kind: GradientKind,
    eps_bits: u64,
    /// Resolved solve-precision tier. f32-tier solves seed their
    /// workspace's lazily built f32 lane; keeping the tiers on
    /// separate entries also halves the cache charge of an f32 entry
    /// (see [`ws_units`]).
    precision: Precision,
    /// Resolved coupling representation (admission stores the
    /// concrete choice). Full-rank and factored workspaces are
    /// different types, and distinct ranks size their thin buffers
    /// differently — each is its own entry.
    coupling: CouplingRank,
}

/// Cache charge of one warm entry against the [`WARM_CACHE_UNITS`]
/// budget: f64-tier full-rank workspaces count 2 capacity units,
/// f32-tier ones 1 (their resident hot state is roughly half the
/// bytes), and factored-coupling entries 1 — an `O((M+N)·r)`
/// [`LrGwWorkspace`] never holds an `M×N` buffer, so even at its
/// maximum rank it is far below a full-rank workspace of the same
/// shape. Screening entries likewise charge 1: a [`SlicedWorkspace`]
/// is `O(S·(P + Σ n_c))` — never M×N.
fn ws_units(key: &WsKey) -> u64 {
    if key.family == "screen"
        || matches!(key.coupling, CouplingRank::LowRank(_))
        || key.precision == Precision::F32Refine
    {
        1
    } else {
        2
    }
}

/// One warm cache slot: the full-rank lockstep batch workspace, the
/// factored-coupling workspace together with the solver it was built
/// from (the solver carries the bound geometry for identity checks
/// and the config the workspace solves under), or the sliced
/// screening workspace (content-agnostic: it holds directions and
/// projection buffers, so any same-shape screen job can reuse it).
enum WarmEntry {
    Full(GwBatchWorkspace),
    LowRank(EntropicGw, LrGwWorkspace),
    Screen(SlicedWorkspace),
}

/// Per-worker LRU of warm workspaces (front = most recent).
struct WarmCache {
    entries: Vec<(WsKey, WarmEntry)>,
}

/// True iff a cached operator's bound geometry pair is exactly the
/// payload's. Grid payloads are fully determined by the [`WsKey`];
/// dense and mixed payloads carry their matrices/grid descriptors,
/// compared here by reference (no clones on the warm path).
fn geometry_matches(gx: &Geometry, gy: &Geometry, payload: &JobPayload) -> bool {
    match payload {
        JobPayload::GwDense { dx, dy, .. } => {
            matches!(gx, Geometry::Dense(d) if d == dx)
                && matches!(gy, Geometry::Dense(d) if d == dy)
        }
        JobPayload::GwMixed { dx, grid, .. } => {
            matches!(gx, Geometry::Dense(d) if d == dx) && gy == grid
        }
        _ => true,
    }
}

impl WarmCache {
    fn new() -> Self {
        WarmCache {
            entries: Vec::new(),
        }
    }

    /// Total cache charge of the live entries.
    fn units(&self) -> u64 {
        self.entries.iter().map(|(k, _)| ws_units(k)).sum()
    }

    /// Drop every entry, returning the gauge charge released (the
    /// panic-respawn path rebuilds the worker's solver state in
    /// place).
    fn reset(&mut self, metrics: &ServiceMetrics) {
        metrics.sub_warm_units(self.units());
        self.entries.clear();
    }

    /// Fetch the workspace for `key`, building one (the only path
    /// that constructs a solver — and, for dense payloads, clones the
    /// geometry) on a miss. Returns `(workspace, was_warm)`.
    ///
    /// Mixed and dense payloads get a middle path between hit and
    /// miss: a cached same-key workspace whose **Y side** matches but
    /// whose dense X support differs is rebound in place via
    /// [`GwBatchWorkspace::swap_dense_x`] — the Y side keeps its
    /// scan/factored state and every solve buffer survives, so a
    /// stream of same-shape dense supports against one fixed target
    /// (the barycenter-style traffic pattern) stays warm instead of
    /// rebuilding the backend per distinct support matrix.
    fn get_or_build(
        &mut self,
        key: &WsKey,
        payload: &JobPayload,
        cfg: &CoordinatorConfig,
        kind: GradientKind,
        batch: usize,
        metrics: &ServiceMetrics,
    ) -> Result<(&mut GwBatchWorkspace, bool)> {
        let pos = self.entries.iter().position(|(k, e)| {
            k == key
                && matches!(e, WarmEntry::Full(ws)
                    if geometry_matches(ws.geom_x(), ws.geom_y(), payload))
        });
        if let Some(pos) = pos {
            let entry = self.entries.remove(pos);
            self.entries.insert(0, entry);
            match &mut self.entries[0].1 {
                WarmEntry::Full(ws) => {
                    ws.ensure_capacity(batch);
                    return Ok((ws, true));
                }
                _ => unreachable!("position matched a full-rank entry"),
            }
        }
        // Same variant, same Y side, different dense X support: swap
        // the dense X side in place. A backend that refuses the swap
        // cannot serve this (or the old) support anymore cheaply —
        // drop the stale entry so the cold build below replaces it
        // instead of duplicating its key in the LRU.
        let rebind = match payload {
            JobPayload::GwMixed { dx, grid, .. } => Some((
                dx,
                self.entries.iter().position(|(k, e)| {
                    k == key && matches!(e, WarmEntry::Full(ws) if ws.geom_y() == grid)
                }),
            )),
            JobPayload::GwDense { dx, dy, .. } => Some((
                dx,
                self.entries.iter().position(|(k, e)| {
                    k == key
                        && matches!(e, WarmEntry::Full(ws)
                            if matches!(ws.geom_y(), Geometry::Dense(d) if d == dy))
                }),
            )),
            _ => None,
        };
        if let Some((dx, Some(pos))) = rebind {
            let mut entry = self.entries.remove(pos);
            let swapped = match &mut entry.1 {
                WarmEntry::Full(ws) => ws.swap_dense_x(dx).is_ok(),
                _ => unreachable!("rebind matched a full-rank entry"),
            };
            if swapped {
                self.entries.insert(0, entry);
                match &mut self.entries[0].1 {
                    WarmEntry::Full(ws) => {
                        ws.ensure_capacity(batch);
                        return Ok((ws, true));
                    }
                    _ => unreachable!("just re-inserted a full entry"),
                }
            }
            metrics.sub_warm_units(ws_units(&entry.0));
        }
        let solver = build_solver(payload, cfg);
        let ws = solver.batch_workspace(kind, batch)?;
        self.entries.insert(0, (key.clone(), WarmEntry::Full(ws)));
        metrics.add_warm_units(ws_units(key));
        // Unit-based LRU eviction: the just-inserted front entry
        // always survives.
        while self.units() > WARM_CACHE_UNITS && self.entries.len() > 1 {
            let (evicted, _) = self.entries.pop().expect("len > 1");
            metrics.sub_warm_units(ws_units(&evicted));
        }
        match &mut self.entries[0].1 {
            WarmEntry::Full(ws) => Ok((ws, false)),
            _ => unreachable!("just inserted a full entry"),
        }
    }

    /// [`WarmCache::get_or_build`] for the factored-coupling path:
    /// fetch (or cold-build) the persistent [`LrGwWorkspace`] — and
    /// the solver whose geometry it is bound to — for `key`. The
    /// workspace's thin state is `O((M+N)·r)`, so an entry charges a
    /// single capacity unit (see [`ws_units`]). Returns
    /// `(solver, workspace, was_warm)`.
    fn get_or_build_lr(
        &mut self,
        key: &WsKey,
        payload: &JobPayload,
        cfg: &CoordinatorConfig,
        rank: usize,
        metrics: &ServiceMetrics,
    ) -> Result<(&EntropicGw, &mut LrGwWorkspace, bool)> {
        let pos = self.entries.iter().position(|(k, e)| {
            k == key
                && matches!(e, WarmEntry::LowRank(solver, _)
                    if geometry_matches(solver.geom_x(), solver.geom_y(), payload))
        });
        if let Some(pos) = pos {
            let entry = self.entries.remove(pos);
            self.entries.insert(0, entry);
            match &mut self.entries[0].1 {
                WarmEntry::LowRank(solver, ws) => return Ok((solver, ws, true)),
                _ => unreachable!("position matched a low-rank entry"),
            }
        }
        let solver = build_solver(payload, cfg);
        let ws = solver.lr_workspace(rank)?;
        self.entries
            .insert(0, (key.clone(), WarmEntry::LowRank(solver, ws)));
        metrics.add_warm_units(ws_units(key));
        while self.units() > WARM_CACHE_UNITS && self.entries.len() > 1 {
            let (evicted, _) = self.entries.pop().expect("len > 1");
            metrics.sub_warm_units(ws_units(&evicted));
        }
        match &mut self.entries[0].1 {
            WarmEntry::LowRank(solver, ws) => Ok((solver, ws, false)),
            _ => unreachable!("just inserted a low-rank entry"),
        }
    }

    /// [`WarmCache::get_or_build`] for the screening path: fetch (or
    /// cold-build) the persistent [`SlicedWorkspace`] for `key`. The
    /// workspace is content-agnostic — it caches directions and
    /// projection buffers keyed by shape, so no geometry check is
    /// needed; a repeat screen of the same envelope allocates nothing.
    /// Returns `(workspace, was_warm)`.
    fn get_or_build_screen(
        &mut self,
        key: &WsKey,
        metrics: &ServiceMetrics,
    ) -> (&mut SlicedWorkspace, bool) {
        let pos = self
            .entries
            .iter()
            .position(|(k, e)| k == key && matches!(e, WarmEntry::Screen(_)));
        if let Some(pos) = pos {
            let entry = self.entries.remove(pos);
            self.entries.insert(0, entry);
            match &mut self.entries[0].1 {
                WarmEntry::Screen(ws) => return (ws, true),
                _ => unreachable!("position matched a screen entry"),
            }
        }
        self.entries.insert(
            0,
            (
                key.clone(),
                WarmEntry::Screen(SlicedWorkspace::with_default_seed()),
            ),
        );
        metrics.add_warm_units(ws_units(key));
        while self.units() > WARM_CACHE_UNITS && self.entries.len() > 1 {
            let (evicted, _) = self.entries.pop().expect("len > 1");
            metrics.sub_warm_units(ws_units(&evicted));
        }
        match &mut self.entries[0].1 {
            WarmEntry::Screen(ws) => (ws, false),
            _ => unreachable!("just inserted a screen entry"),
        }
    }
}

fn native_worker_loop(q: ShardedQueue<Envelope>, ctx: WorkerCtx) {
    let mut pinned: Option<usize> = None;
    let mut cache = WarmCache::new();
    let mut streak = 0usize;
    loop {
        // After a bounded streak of same-shard batches, rotate to the
        // longest other non-empty shard so a sustained hot variant
        // cannot starve jobs queued elsewhere.
        let rotate = streak >= PIN_STREAK_MAX;
        let Some(batch) = q.pop_batch_pinned(&mut pinned, ctx.cfg.batch_max.max(1), rotate) else {
            break;
        };
        if batch.shed {
            // Depth-aware pin expiry (a shed is also a steal below).
            ctx.metrics.on_shed();
        }
        if batch.stolen {
            ctx.metrics.on_steal();
            streak = 0;
        } else {
            streak = streak.saturating_add(1);
        }
        let (reqs, txs): (Vec<JobRequest>, Vec<mpsc::Sender<JobResult>>) =
            batch.items.into_iter().unzip();
        let mut tx_by_id: HashMap<JobId, mpsc::Sender<JobResult>> =
            reqs.iter().map(|r| r.id).zip(txs).collect();
        // Fail-fast drain: `shutdown_now` turns still-queued jobs into
        // terminal rejections instead of burning solve time on them.
        if ctx.draining.load(Ordering::SeqCst) {
            for req in &reqs {
                let result = rejected_result(req, "coordinator shutting down");
                deliver(&mut tx_by_id, &ctx.metrics, result);
            }
            continue;
        }
        // Dequeue-side deadline enforcement: a job whose deadline
        // lapsed while it queued is shed with a terminal result — it
        // never costs solve time.
        let (live, expired): (Vec<JobRequest>, Vec<JobRequest>) =
            reqs.into_iter().partition(|r| !r.expired());
        for req in expired {
            ctx.metrics.on_deadline_shed();
            let result = rejected_result(&req, "deadline expired in queue");
            deliver(&mut tx_by_id, &ctx.metrics, result);
        }
        // A shard is keyed by variant hash, so a popped batch is
        // overwhelmingly one variant already; the grouping both
        // handles hash collisions and splits on ε (a solver knob).
        for (_variant, _eps, group) in group_for_execution(live) {
            for sub in split_same_geometry(group) {
                for result in execute_group_contained(&sub, &ctx, &mut cache) {
                    deliver(&mut tx_by_id, &ctx.metrics, result);
                }
            }
        }
    }
}

/// Report and deliver one result. An undeliverable result — the
/// caller dropped its receiver, or an id the batch never carried — is
/// counted, never a panic: a caller walking away must not take the
/// worker (and every co-batched job) down with it.
fn deliver(
    tx_by_id: &mut HashMap<JobId, mpsc::Sender<JobResult>>,
    metrics: &ServiceMetrics,
    result: JobResult,
) {
    report(metrics, &result);
    match tx_by_id.remove(&result.id) {
        Some(tx) => {
            if tx.send(result).is_err() {
                metrics.on_lost_result();
            }
        }
        None => metrics.on_lost_result(),
    }
}

/// Both sides' support sizes for a payload (the geometry shape a
/// batch must agree on).
fn payload_dims(p: &JobPayload) -> (usize, usize) {
    match p {
        JobPayload::Gw1d { u, v, .. }
        | JobPayload::Fgw1d { u, v, .. }
        | JobPayload::GwDense { u, v, .. }
        | JobPayload::GwMixed { u, v, .. } => (u.len(), v.len()),
        JobPayload::Gw2d { n, .. } => (n * n, n * n),
        JobPayload::Gw3d { n, .. } => (n * n * n, n * n * n),
        // The escalated exact solves pair the query with one candidate
        // at a time — the largest candidate bounds the target side.
        JobPayload::GwScreen {
            query, candidates, ..
        } => (
            query.rows(),
            candidates.iter().map(Mat::rows).max().unwrap_or(0),
        ),
    }
}

/// An execution group must further split into runs that truly share
/// one operator: equal `(M, N)` shapes (the variant key only carries
/// the source-side size — FGW pairs may differ on the target side)
/// and, for dense and mixed payloads, *equal* carried geometries (they
/// travel in the payload). Dense-matrix equality is decided by the
/// content fingerprint stamped at admission — the `O(N²)` matrix
/// compare only runs on a fingerprint match, as the collision guard;
/// a mixed payload's grid side is an `O(1)` descriptor compare.
fn split_same_geometry(jobs: Vec<JobRequest>) -> Vec<Vec<JobRequest>> {
    let mut out: Vec<Vec<JobRequest>> = Vec::new();
    for job in jobs {
        let pos = out.iter().position(|bucket| {
            let head = &bucket[0];
            if payload_dims(&head.payload) != payload_dims(&job.payload) {
                return false;
            }
            match (&head.payload, &job.payload) {
                (
                    JobPayload::GwDense {
                        fingerprint: fa,
                        dx: ax,
                        dy: ay,
                        ..
                    },
                    JobPayload::GwDense {
                        fingerprint: fb,
                        dx: bx,
                        dy: by,
                        ..
                    },
                ) => fa == fb && ax == bx && ay == by,
                (JobPayload::GwDense { .. }, _) | (_, JobPayload::GwDense { .. }) => false,
                (
                    JobPayload::GwMixed {
                        fingerprint: fa,
                        dx: ax,
                        grid: ga,
                        ..
                    },
                    JobPayload::GwMixed {
                        fingerprint: fb,
                        dx: bx,
                        grid: gb,
                        ..
                    },
                ) => ga == gb && fa == fb && ax == bx,
                (JobPayload::GwMixed { .. }, _) | (_, JobPayload::GwMixed { .. }) => false,
                _ => true,
            }
        });
        match pos {
            Some(i) => out[i].push(job),
            None => out.push(vec![job]),
        }
    }
    out
}

fn pjrt_worker_loop(q: BoundedQueue<Envelope>, ctx: WorkerCtx, registry: ArtifactRegistry) {
    let mut executor = match Executor::cpu() {
        Ok(e) => Some(e),
        Err(e) => {
            eprintln!("[fgcgw] PJRT unavailable ({e}); falling back to native");
            None
        }
    };
    while let Some((req, tx)) = q.pop() {
        let result = if ctx.draining.load(Ordering::SeqCst) {
            // Fail-fast drain (`shutdown_now`).
            rejected_result(&req, "coordinator shutting down")
        } else if req.expired() {
            ctx.metrics.on_deadline_shed();
            rejected_result(&req, "deadline expired in queue")
        } else {
            let attempt = catch_unwind(AssertUnwindSafe(|| {
                match (&req.backend, executor.as_mut()) {
                    (BackendChoice::Pjrt(name), Some(ex)) => {
                        match execute_pjrt(ex, &registry, name, &req) {
                            Ok(r) => r,
                            Err(e) => {
                                // Artifact failure → native fallback
                                // keeps the job alive; record the
                                // downgraded backend.
                                eprintln!("[fgcgw] pjrt {name} failed ({e}); native fallback");
                                let mut r = execute_solo_with_recovery(
                                    &req,
                                    &ctx.cfg,
                                    &ctx.metrics,
                                    &ctx.faults,
                                    Prior::None,
                                );
                                r.backend = BackendChoice::NativeFgc;
                                r
                            }
                        }
                    }
                    _ => {
                        // Executor unavailable: the job runs natively,
                        // so the result (and the per-backend metrics)
                        // must say so.
                        let mut r = execute_solo_with_recovery(
                            &req,
                            &ctx.cfg,
                            &ctx.metrics,
                            &ctx.faults,
                            Prior::None,
                        );
                        if matches!(req.backend, BackendChoice::Pjrt(_)) {
                            r.backend = BackendChoice::NativeFgc;
                        }
                        r
                    }
                }
            }));
            match attempt {
                Ok(r) => r,
                Err(payload) => {
                    // The worker thread survives the panic; the
                    // executor's state across an unwound PJRT call may
                    // not have — rebuild it in place.
                    ctx.metrics.on_panic();
                    executor = Executor::cpu().ok();
                    ctx.metrics.on_respawn();
                    JobResult {
                        id: req.id,
                        objective: Err(Error::Runtime(format!(
                            "worker panic: {}",
                            panic_message(payload)
                        ))
                        .to_string()),
                        plan: None,
                        backend: req.backend.clone(),
                        family: req.payload.family(),
                        queue_time: req.submitted_at.elapsed(),
                        solve_time: Duration::ZERO,
                        screen: None,
                    }
                }
            }
        };
        report(&ctx.metrics, &result);
        if tx.send(result).is_err() {
            ctx.metrics.on_lost_result();
        }
    }
}

fn report(metrics: &ServiceMetrics, result: &JobResult) {
    // Count the backend that actually ran (PJRT failures downgrade to
    // native in `result.backend`).
    metrics.on_complete(
        &result.backend,
        result.family,
        result.objective.is_ok(),
        result.queue_time,
        result.solve_time,
    );
}

/// The warm-cache identity of a payload — derived from the payload
/// alone, so cache lookups never construct a solver (or clone dense
/// geometries).
fn ws_key(
    payload: &JobPayload,
    kind: GradientKind,
    precision: Precision,
    coupling: CouplingRank,
) -> WsKey {
    let (family, m, n, k) = match payload {
        JobPayload::Gw1d { u, v, k, .. } => ("grid1d", u.len(), v.len(), *k),
        // FGW shares the GW geometry — the feature term is per job.
        JobPayload::Fgw1d { u, v, k, .. } => ("grid1d", u.len(), v.len(), *k),
        JobPayload::Gw2d { n, k, .. } => ("grid2d", n * n, n * n, *k),
        JobPayload::Gw3d { n, k, .. } => ("grid3d", n * n * n, n * n * n, *k),
        JobPayload::GwDense { u, v, .. } => ("dense", u.len(), v.len(), 0),
        // The family carries the grid side's dimension so mixed jobs
        // with different structured sides never share a key; spacing
        // and the dense matrix are checked by geometry_matches / the
        // rebind path.
        JobPayload::GwMixed { u, v, grid, .. } => (
            match grid {
                Geometry::Grid1d { .. } => "mixed1d",
                Geometry::Grid2d { .. } => "mixed2d",
                Geometry::Grid3d { .. } => "mixed3d",
                Geometry::Dense(_) => "mixeddense", // rejected at admission
            },
            u.len(),
            v.len(),
            grid.grid_exponent().unwrap_or(0),
        ),
        // A screen workspace is shaped by (query points, candidate
        // envelope); the candidate count rides in `k` so differently
        // sized screens never share buffers sized for each other.
        JobPayload::GwScreen {
            query, candidates, ..
        } => (
            "screen",
            query.rows(),
            candidates.iter().map(Mat::rows).max().unwrap_or(0),
            candidates.len() as u32,
        ),
    };
    WsKey {
        family,
        m,
        n,
        k,
        kind,
        eps_bits: payload.epsilon().to_bits(),
        precision,
        coupling,
    }
}

/// Build the solver for a payload (cache-miss path only: for dense
/// payloads this clones the distance matrices into the geometry).
fn build_solver(payload: &JobPayload, cfg: &CoordinatorConfig) -> EntropicGw {
    build_solver_with_epsilon(payload, cfg, payload.epsilon())
}

/// [`build_solver`] with an explicit ε — the anneal rung of the
/// degradation ladder solves at ε·2, and derived knobs (the low-rank
/// factorization tolerance) must follow the ε actually solved at.
fn build_solver_with_epsilon(
    payload: &JobPayload,
    cfg: &CoordinatorConfig,
    epsilon: f64,
) -> EntropicGw {
    // The precision tier is a per-solve knob passed at `solve_batch`
    // time; the cfg baked into the solver here only seeds workspace
    // construction (threads), so it stays on the f64 default.
    let gcfg = gw_cfg(cfg, epsilon, Precision::F64);
    let solver = match payload {
        JobPayload::Gw1d { u, v, k, .. } | JobPayload::Fgw1d { u, v, k, .. } => {
            EntropicGw::grid_1d(u.len(), v.len(), *k, gcfg)
        }
        JobPayload::Gw2d { n, k, .. } => EntropicGw::grid_2d(*n, *n, *k, gcfg),
        JobPayload::Gw3d { n, k, .. } => EntropicGw::grid_3d(*n, *n, *k, gcfg),
        JobPayload::GwDense { dx, dy, .. } => EntropicGw::new(
            Geometry::Dense(dx.clone()),
            Geometry::Dense(dy.clone()),
            gcfg,
        ),
        JobPayload::GwMixed { dx, grid, .. } => {
            EntropicGw::new(Geometry::Dense(dx.clone()), grid.clone(), gcfg)
        }
        // Screen jobs never reach the solver-build path: the fused
        // branch and the solo path both route them through
        // `run_screen`, whose escalation builds per-candidate solvers.
        JobPayload::GwScreen { .. } => {
            unreachable!("screen jobs solve through the sliced path")
        }
    };
    if cfg.lowrank_tol > 0.0 {
        solver.with_lowrank_options(LowRankOptions {
            tol: cfg.lowrank_tol,
            max_rank: 0,
        })
    } else {
        solver
    }
}

/// A payload's per-job batch entry (marginals + optional FGW term).
fn batch_job(payload: &JobPayload) -> BatchJob<'_> {
    match payload {
        JobPayload::Gw1d { u, v, .. }
        | JobPayload::Gw2d { u, v, .. }
        | JobPayload::Gw3d { u, v, .. }
        | JobPayload::GwDense { u, v, .. }
        | JobPayload::GwMixed { u, v, .. } => BatchJob::gw(u, v),
        JobPayload::GwScreen { .. } => {
            unreachable!("screen jobs solve through the sliced path")
        }
        JobPayload::Fgw1d {
            u,
            v,
            feature_cost,
            theta,
            ..
        } => BatchJob {
            u,
            v,
            feature_cost: Some(feature_cost),
            theta: *theta,
        },
    }
}

/// One fused lockstep attempt at a same-variant same-ε same-geometry
/// group over the worker's warm workspace. Results are bit-for-bit
/// what independent per-job solves produce (the batch contract of
/// [`EntropicGw::solve_batch_into`]). `Ok` only when the whole batch
/// solved; any failure comes back as the typed error so
/// [`execute_group_contained`] can recover instead of failing every
/// member.
fn execute_group_fused(
    reqs: &[JobRequest],
    ctx: &WorkerCtx,
    cache: &mut WarmCache,
) -> Result<Vec<JobResult>> {
    debug_assert!(!reqs.is_empty());
    let queue_times: Vec<Duration> = reqs.iter().map(|r| r.submitted_at.elapsed()).collect();
    let kind = reqs[0].backend.gradient_kind();
    // Admission stored the resolved tier; the variant key split on it,
    // so the whole group agrees.
    let precision = reqs[0].options.precision.unwrap_or(Precision::F64);
    // Admission resolved the coupling representation; the variant key
    // split on it, so the whole group agrees.
    let coupling = reqs[0].options.coupling.unwrap_or(CouplingRank::Full);
    let started = Instant::now();
    let head = &reqs[0].payload;
    let key = ws_key(head, kind, precision, coupling);
    let b = reqs.len() as u64;
    if matches!(head, JobPayload::GwScreen { .. }) {
        // Screening path: each job of the group runs through the
        // worker's persistent sliced workspace (content-agnostic, so
        // any same-shape screen reuses its buffers), then escalates
        // its top-k hits to exact solves. No M×N work happens outside
        // the escalated pairs.
        let (ws, warm) = cache.get_or_build_screen(&key, &ctx.metrics);
        if warm {
            ctx.metrics.on_warm(b, 0);
        } else {
            ctx.metrics.on_warm(b - 1, 1);
        }
        let mut out = Vec::with_capacity(reqs.len());
        for (req, queue_time) in reqs.iter().zip(queue_times) {
            ctx.faults.fire(req.id)?;
            let attempt_started = Instant::now();
            let (objective, plan, outcome) = run_screen(req, &ctx.cfg, ws, 1.0)?;
            ctx.metrics.on_screened(outcome.scores.len() as u64);
            ctx.metrics.on_escalated(outcome.hits.len() as u64);
            out.push(JobResult {
                id: req.id,
                objective: Ok(objective),
                plan: Some(plan),
                backend: req.backend.clone(),
                family: req.payload.family(),
                queue_time,
                solve_time: attempt_started.elapsed(),
                screen: Some(outcome),
            });
        }
        return Ok(out);
    }
    if let CouplingRank::LowRank(rank) = coupling {
        // Factored-coupling serving path: each job of the group runs
        // through the worker's persistent O((M+N)·r) workspace — no
        // M×N coupling is ever materialized inside the solve (the
        // returned plan is; large-plan elision is a client concern).
        let (solver, lr_ws, warm) =
            cache.get_or_build_lr(&key, head, &ctx.cfg, rank, &ctx.metrics)?;
        if warm {
            ctx.metrics.on_warm(b, 0);
        } else {
            ctx.metrics.on_warm(b - 1, 1);
        }
        let mut out = Vec::with_capacity(reqs.len());
        for (req, queue_time) in reqs.iter().zip(queue_times) {
            ctx.faults.fire(req.id)?;
            lr_ws.set_deadline(req.deadline_instant());
            let job = batch_job(&req.payload);
            let attempt_started = Instant::now();
            let sol = solver.solve_lowrank_into(job.u, job.v, lr_ws)?;
            out.push(JobResult {
                id: req.id,
                objective: Ok(sol.objective),
                plan: Some(sol.plan()),
                backend: req.backend.clone(),
                family: req.payload.family(),
                queue_time,
                solve_time: attempt_started.elapsed(),
                screen: None,
            });
        }
        return Ok(out);
    }
    let (ws, warm) = cache.get_or_build(&key, head, &ctx.cfg, kind, reqs.len(), &ctx.metrics)?;
    if warm {
        ctx.metrics.on_warm(b, 0);
    } else {
        ctx.metrics.on_warm(b - 1, 1);
    }
    if precision == Precision::F32Refine {
        ctx.metrics.on_f32_served(b);
    }
    // Scripted faults: a member's panic/numeric arm fails this fused
    // attempt (containment then isolates it); a scripted misprediction
    // forces the batch onto the Gibbs regime regardless of the
    // predictor, exercising the demote-and-retry path.
    for req in reqs {
        ctx.faults.fire(req.id)?;
    }
    if reqs.iter().any(|r| ctx.faults.mispredict(r.id)) {
        ws.set_regime_override(Some(Regime::Gibbs));
    }
    let jobs: Vec<BatchJob> = reqs.iter().map(|r| batch_job(&r.payload)).collect();
    // Warm path: solve against the workspace's own bound geometry
    // — no solver construction, no dense-geometry clones.
    let sols = ws.solve_batch(&gw_cfg(&ctx.cfg, head.epsilon(), precision), &jobs)?;
    // Lockstep wall time is shared; report the per-job mean so the
    // latency accounting stays comparable with per-job execution.
    let solve_each = started.elapsed() / reqs.len().max(1) as u32;
    Ok(reqs
        .iter()
        .zip(queue_times)
        .zip(sols)
        .map(|((req, queue_time), sol)| JobResult {
            id: req.id,
            objective: Ok(sol.objective),
            plan: Some(sol.plan),
            backend: req.backend.clone(),
            family: req.payload.family(),
            queue_time,
            solve_time: solve_each,
            screen: None,
        })
        .collect())
}

/// Panic-isolated, blast-radius-contained execution of one group.
///
/// The fused warm-path attempt runs under `catch_unwind`; a panic
/// respawns the worker's solver state in place (fresh warm cache — the
/// thread itself never dies), and any failure of a multi-member batch
/// splits it so every member is re-executed solo and no job inherits a
/// co-batched neighbor's failure. Single jobs enter the solo recovery
/// ladder directly with the failure as their prior.
fn execute_group_contained(
    reqs: &[JobRequest],
    ctx: &WorkerCtx,
    cache: &mut WarmCache,
) -> Vec<JobResult> {
    let attempt = catch_unwind(AssertUnwindSafe(|| execute_group_fused(reqs, ctx, cache)));
    let prior = match attempt {
        Ok(Ok(results)) => return results,
        Ok(Err(e)) => match e {
            Error::Numeric(_) => Prior::Numeric(e.to_string()),
            other => Prior::Fatal(other.to_string()),
        },
        Err(payload) => {
            // The worker thread survives the panic, but the warm
            // workspaces it unwound through may hold torn state —
            // rebuild the worker's solver state in place.
            ctx.metrics.on_panic();
            cache.reset(&ctx.metrics);
            ctx.metrics.on_respawn();
            Prior::Panicked(panic_message(payload))
        }
    };
    if reqs.len() == 1 {
        return vec![execute_solo_with_recovery(
            &reqs[0],
            &ctx.cfg,
            &ctx.metrics,
            &ctx.faults,
            prior,
        )];
    }
    // Blast-radius containment: one member's failure must not fail its
    // co-batched neighbors. Split the group and re-execute each member
    // solo — the lockstep batch contract guarantees a survivor's solo
    // result is bit-for-bit the result the batch would have produced.
    ctx.metrics.on_batch_split();
    reqs.iter()
        .map(|req| {
            execute_solo_with_recovery(req, &ctx.cfg, &ctx.metrics, &ctx.faults, Prior::None)
        })
        .collect()
}

/// What already happened to a job before it entered solo recovery.
enum Prior {
    /// Nothing — start with a clean attempt.
    None,
    /// A numeric failure: enter the degradation ladder immediately.
    Numeric(String),
    /// A deterministic non-numeric error — retrying cannot help.
    Fatal(String),
    /// A caught panic — counts toward the quarantine budget.
    Panicked(String),
}

/// Panicking execution attempts a job gets (the fused batch attempt
/// counts as one) before it is quarantined with a terminal error
/// instead of being retried again.
const QUARANTINE_ATTEMPTS: usize = 2;

/// Per-attempt solve knobs the degradation ladder adjusts.
struct SolveOverrides {
    /// Force the log-domain Sinkhorn regime (rung 1).
    force_log: bool,
    /// Scale the job's ε (rung 2 anneals by 2).
    epsilon_scale: f64,
    /// Swap the gradient backend (rung 3: lowrank → naive).
    kind_override: Option<GradientKind>,
}

/// Climb to the next rung of the degradation ladder within the job's
/// retry budget: forced log-domain regime, then ε·2 anneal, then — for
/// dense payloads on the low-rank backend — the exact naive gradient
/// at the job's own ε. Returns `false` when the budget is exhausted or
/// no further rung applies to this job.
fn climb(
    rung: &mut u32,
    ov: &mut SolveOverrides,
    req: &JobRequest,
    metrics: &ServiceMetrics,
) -> bool {
    loop {
        if *rung >= req.options.max_retries {
            return false;
        }
        match *rung {
            0 => {
                *rung = 1;
                ov.force_log = true;
                metrics.on_retry_regime();
                return true;
            }
            1 => {
                *rung = 2;
                ov.epsilon_scale = 2.0;
                metrics.on_retry_anneal();
                return true;
            }
            2 => {
                *rung = 3;
                // The backend rung exists only where an exact fallback
                // does: dense payloads running the low-rank gradient.
                // The anneal rolls back — the naive backend retries at
                // the job's own ε with the default regime pick.
                if matches!(req.payload, JobPayload::GwDense { .. })
                    && req.backend.gradient_kind() == GradientKind::LowRank
                {
                    ov.kind_override = Some(GradientKind::Naive);
                    ov.force_log = false;
                    ov.epsilon_scale = 1.0;
                    metrics.on_retry_backend();
                    return true;
                }
            }
            _ => return false,
        }
    }
}

/// Run one job to a terminal result on a fresh solver, with panic
/// isolation (quarantine after [`QUARANTINE_ATTEMPTS`] panicking
/// attempts), the numeric degradation ladder ([`climb`]), and deadline
/// enforcement between attempts and between outer iterations.
fn execute_solo_with_recovery(
    req: &JobRequest,
    cfg: &CoordinatorConfig,
    metrics: &ServiceMetrics,
    faults: &Faults,
    prior: Prior,
) -> JobResult {
    let queue_time = req.submitted_at.elapsed();
    let started = Instant::now();
    let fail = |msg: String, solve_time: Duration| JobResult {
        id: req.id,
        objective: Err(msg),
        plan: None,
        backend: req.backend.clone(),
        family: req.payload.family(),
        queue_time,
        solve_time,
        screen: None,
    };
    let mut ov = SolveOverrides {
        force_log: false,
        epsilon_scale: 1.0,
        kind_override: None,
    };
    let mut rung = 0u32;
    let mut panics = 0usize;
    match prior {
        Prior::None => {}
        Prior::Fatal(msg) => return fail(msg, Duration::ZERO),
        Prior::Numeric(msg) => {
            if !climb(&mut rung, &mut ov, req, metrics) {
                return fail(msg, Duration::ZERO);
            }
        }
        Prior::Panicked(_) => panics = 1,
    }
    loop {
        if req.expired() {
            metrics.on_deadline_shed();
            return fail(
                Error::Rejected("deadline expired during recovery".into()).to_string(),
                started.elapsed(),
            );
        }
        match catch_unwind(AssertUnwindSafe(|| solve_solo(req, cfg, faults, &ov))) {
            Ok(Ok((objective, plan, screen))) => {
                if let Some(sc) = &screen {
                    metrics.on_screened(sc.scores.len() as u64);
                    metrics.on_escalated(sc.hits.len() as u64);
                }
                // A backend-rung success ran a different gradient than
                // routed — the result (and per-backend metrics) must
                // say which backend actually produced it.
                let backend = match ov.kind_override {
                    Some(kind) => BackendChoice::native(kind),
                    None => req.backend.clone(),
                };
                return JobResult {
                    id: req.id,
                    objective: Ok(objective),
                    plan: Some(plan),
                    backend,
                    family: req.payload.family(),
                    queue_time,
                    solve_time: started.elapsed(),
                    screen,
                };
            }
            Ok(Err(e)) => {
                if matches!(e, Error::Numeric(_)) && climb(&mut rung, &mut ov, req, metrics) {
                    continue;
                }
                return fail(e.to_string(), started.elapsed());
            }
            Err(payload) => {
                metrics.on_panic();
                metrics.on_respawn();
                panics += 1;
                if panics >= QUARANTINE_ATTEMPTS {
                    metrics.on_quarantine();
                    return fail(
                        format!(
                            "job quarantined after {panics} panicking attempts: {}",
                            panic_message(payload)
                        ),
                        started.elapsed(),
                    );
                }
            }
        }
    }
}

/// One screening pass + escalation for a [`JobPayload::GwScreen`]
/// job: resolve the slice count (explicit > deadline-budget policy >
/// default), screen through `ws`, escalate the top-k to exact solves,
/// and return the best hit's `(objective, plan)` with the full
/// [`ScreenOutcome`]. The slice count is derived from the job's
/// *configured* deadline, not remaining wall time, so identical jobs
/// always screen identically. `epsilon_scale` is the degradation
/// ladder's anneal knob — it reaches only the escalated exact solves
/// (the screen itself has no ε).
fn run_screen(
    req: &JobRequest,
    cfg: &CoordinatorConfig,
    ws: &mut SlicedWorkspace,
    epsilon_scale: f64,
) -> Result<(f64, Mat, ScreenOutcome)> {
    let JobPayload::GwScreen {
        query,
        candidates,
        top_k,
        slices,
        warm_start,
        epsilon,
        ..
    } = &req.payload
    else {
        return Err(Error::Invalid("run_screen needs a GwScreen payload".into()));
    };
    let slices = if *slices > 0 {
        *slices
    } else if let Some(budget) = req.options.deadline {
        let total: usize = candidates.iter().map(Mat::rows).sum();
        screen_slices(query.rows(), total, budget)
    } else {
        SCREEN_SLICES_DEFAULT
    };
    let scfg = SlicedConfig {
        slices,
        threads: cfg.solver_threads,
        ..SlicedConfig::default()
    };
    ws.screen_into(query, candidates, &scfg)?;
    let gcfg = gw_cfg(cfg, epsilon * epsilon_scale, Precision::F64);
    let hits = ws.escalate(
        query,
        candidates,
        *top_k,
        &gcfg,
        req.backend.gradient_kind(),
        *warm_start,
        req.deadline_instant(),
    )?;
    let outcome = ScreenOutcome {
        scores: ws.scores().to_vec(),
        hits: hits
            .iter()
            .map(|h| ScreenHit {
                candidate: h.candidate,
                sliced_score: h.sliced_score,
                objective: h.solution.objective,
            })
            .collect(),
        slices,
    };
    let best = hits
        .into_iter()
        .next()
        .ok_or_else(|| Error::Runtime("escalation returned no hits".into()))?;
    Ok((best.solution.objective, best.solution.plan, outcome))
}

/// One solo attempt at a job on a fresh solver, honoring the ladder's
/// overrides, the job's deadline, and any scripted faults. The third
/// element of a success is the screening report (`Some` only for
/// screen jobs).
fn solve_solo(
    req: &JobRequest,
    cfg: &CoordinatorConfig,
    faults: &Faults,
    ov: &SolveOverrides,
) -> Result<(f64, Mat, Option<ScreenOutcome>)> {
    faults.fire(req.id)?;
    // Screen jobs recover on the sliced path with a fresh workspace
    // (the ladder's ε-anneal rung reaches their escalated solves; the
    // regime/backend rungs don't apply).
    if matches!(req.payload, JobPayload::GwScreen { .. }) {
        let mut ws = SlicedWorkspace::with_default_seed();
        let (objective, plan, outcome) = run_screen(req, cfg, &mut ws, ov.epsilon_scale)?;
        return Ok((objective, plan, Some(outcome)));
    }
    let kind = ov
        .kind_override
        .unwrap_or_else(|| req.backend.gradient_kind());
    let epsilon = req.payload.epsilon() * ov.epsilon_scale;
    // A factored-coupling job recovers on the factored path (its
    // full-rank twin may not even fit in memory at serving scale);
    // only the ladder's exact-backend rung — which exists to swap the
    // approximation out entirely — demotes it to full rank.
    let coupling = match ov.kind_override {
        Some(_) => CouplingRank::Full,
        None => req.options.coupling.unwrap_or(CouplingRank::Full),
    };
    if let CouplingRank::LowRank(rank) = coupling {
        let solver = build_solver_with_epsilon(&req.payload, cfg, epsilon);
        let mut lr_ws = solver.lr_workspace(rank)?;
        lr_ws.set_deadline(req.deadline_instant());
        let job = batch_job(&req.payload);
        let sol = solver.solve_lowrank_into(job.u, job.v, &mut lr_ws)?;
        return Ok((sol.objective, sol.plan(), None));
    }
    let solver = build_solver_with_epsilon(&req.payload, cfg, epsilon);
    let mut ws = solver.batch_workspace(kind, 1)?;
    if faults.mispredict(req.id) {
        ws.set_regime_override(Some(Regime::Gibbs));
    }
    if ov.force_log {
        // The ladder's forced log-domain rung wins over a scripted
        // misprediction — that is the recovery under test.
        ws.set_regime_override(Some(Regime::Log));
    }
    ws.set_deadline(req.deadline_instant());
    let job = batch_job(&req.payload);
    // Recovery always solves pure f64: a job that already failed (or
    // fell back from PJRT) gets the most robust numeric path, not the
    // throughput tier.
    let mut sols = ws.solve_batch(&gw_cfg(cfg, epsilon, Precision::F64), &[job])?;
    let sol = sols
        .pop()
        .ok_or_else(|| Error::Runtime("batch solve returned no solution".into()))?;
    Ok((sol.objective, sol.plan, None))
}

/// Terminal result for a job the service will not solve (deadline
/// shed, fail-fast shutdown drain).
fn rejected_result(req: &JobRequest, why: &str) -> JobResult {
    JobResult {
        id: req.id,
        objective: Err(Error::Rejected(why.to_string()).to_string()),
        plan: None,
        backend: req.backend.clone(),
        family: req.payload.family(),
        queue_time: req.submitted_at.elapsed(),
        solve_time: Duration::ZERO,
        screen: None,
    }
}

/// Human-readable panic payload (covers the `&str`/`String` cases
/// every `panic!` in this crate produces).
fn panic_message(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "worker panicked".into()
    }
}

/// Run a job through a compiled artifact.
fn execute_pjrt(
    executor: &mut Executor,
    registry: &ArtifactRegistry,
    name: &str,
    req: &JobRequest,
) -> Result<JobResult> {
    let queue_time = req.submitted_at.elapsed();
    let spec = registry
        .by_name(name)
        .ok_or_else(|| Error::ArtifactNotFound(name.to_string()))?;
    let started = Instant::now();
    let out = match &req.payload {
        JobPayload::Gw1d { u, v, .. } | JobPayload::Gw2d { u, v, .. } => {
            executor.run_gw_solve(spec, u, v)?
        }
        JobPayload::Fgw1d {
            u, v, feature_cost, ..
        } => executor.run_fgw_solve(spec, u, v, feature_cost)?,
        // The router never assigns dense, mixed, 3D or screen jobs to
        // PJRT (no compiled artifact families exist for these shapes).
        JobPayload::Gw3d { .. }
        | JobPayload::GwDense { .. }
        | JobPayload::GwMixed { .. }
        | JobPayload::GwScreen { .. } => {
            return Err(Error::Runtime(
                "no PJRT artifact family for dense/mixed/3D/screen jobs".into(),
            ))
        }
    };
    Ok(JobResult {
        id: req.id,
        objective: Ok(out.objective),
        plan: Some(out.plan),
        backend: req.backend.clone(),
        family: req.payload.family(),
        queue_time,
        solve_time: started.elapsed(),
        screen: None,
    })
}

fn gw_cfg(cfg: &CoordinatorConfig, epsilon: f64, precision: Precision) -> GwConfig {
    GwConfig {
        epsilon,
        outer_iters: cfg.outer_iters,
        sinkhorn_max_iters: cfg.sinkhorn_max_iters,
        sinkhorn_tolerance: cfg.sinkhorn_tolerance,
        sinkhorn_check_every: 10,
        threads: cfg.solver_threads,
        precision,
        // The coupling representation is dispatched by the service
        // (factored jobs run through [`WarmCache::get_or_build_lr`]);
        // the solver config underneath always describes the full-rank
        // path the batch workspaces execute.
        coupling: CouplingRank::Full,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::random_distribution;
    use crate::prng::Rng;

    fn test_cfg() -> CoordinatorConfig {
        CoordinatorConfig {
            native_workers: 2,
            shards: 4,
            queue_capacity: 16,
            batch_max: 4,
            artifacts_dir: PathBuf::from("/nonexistent"),
            policy: RoutingPolicy::PreferPjrt,
            enable_pjrt: false,
            outer_iters: 5,
            sinkhorn_max_iters: 300,
            sinkhorn_tolerance: 1e-8,
            solver_threads: 2,
            lowrank_tol: 0.0,
            precision: Precision::F64,
            coupling: None,
            submit_timeout: Duration::from_millis(100),
            default_deadline: None,
            default_max_retries: 3,
        }
    }

    fn gw_payload(n: usize, seed: u64) -> JobPayload {
        let mut rng = Rng::seeded(seed);
        JobPayload::Gw1d {
            u: random_distribution(&mut rng, n),
            v: random_distribution(&mut rng, n),
            k: 1,
            epsilon: 0.01,
        }
    }

    #[test]
    fn end_to_end_native_solve() {
        let coord = Coordinator::start(test_cfg()).unwrap();
        let res = coord.submit_and_wait(gw_payload(20, 1)).unwrap();
        assert!(res.objective.is_ok());
        assert!(res.plan.is_some());
        assert_eq!(res.backend, BackendChoice::NativeFgc);
        let snap = coord.metrics();
        assert_eq!(snap.completed, 1);
        assert_eq!(snap.shard_depths.len(), 4);
        assert_eq!(snap.warm_hits + snap.warm_misses, 1);
        coord.shutdown();
    }

    #[test]
    fn many_jobs_all_complete() {
        let coord = Coordinator::start(test_cfg()).unwrap();
        let rxs: Vec<_> = (0..10)
            .map(|i| coord.submit(gw_payload(12 + (i % 3), 100 + i as u64)).unwrap().1)
            .collect();
        for rx in rxs {
            let res = rx.recv().unwrap();
            assert!(res.objective.is_ok(), "{:?}", res.objective);
        }
        assert_eq!(coord.metrics().completed, 10);
        coord.shutdown();
    }

    #[test]
    fn invalid_payload_rejected_at_admission() {
        let coord = Coordinator::start(test_cfg()).unwrap();
        let bad = JobPayload::Gw1d {
            u: vec![0.7, 0.7],
            v: vec![0.5, 0.5],
            k: 1,
            epsilon: 0.01,
        };
        assert!(coord.submit(bad).is_err());
        assert_eq!(coord.metrics().rejected, 1);
        coord.shutdown();
    }

    #[test]
    fn shutdown_is_clean_with_pending_results() {
        let coord = Coordinator::start(test_cfg()).unwrap();
        let (_, rx) = coord.submit(gw_payload(16, 9)).unwrap();
        coord.shutdown(); // workers drain before exiting
        assert!(rx.recv().unwrap().objective.is_ok());
    }

    #[test]
    fn auto_shards_follow_worker_count() {
        let mut cfg = test_cfg();
        cfg.shards = 0;
        cfg.native_workers = 3;
        let coord = Coordinator::start(cfg).unwrap();
        assert_eq!(coord.shards(), 6);
        assert_eq!(coord.metrics().shard_depths.len(), 6);
        coord.shutdown();
    }

    #[test]
    fn same_variant_burst_is_mostly_warm() {
        // One worker, one variant: the first job builds the workspace,
        // everything after must hit it (the acceptance bar is ≥ 90%).
        let mut cfg = test_cfg();
        cfg.native_workers = 1;
        cfg.queue_capacity = 64;
        cfg.submit_timeout = Duration::from_secs(10);
        let coord = Coordinator::start(cfg).unwrap();
        let jobs = 24;
        let rxs: Vec<_> = (0..jobs)
            .map(|i| coord.submit(gw_payload(18, 500 + i as u64)).unwrap().1)
            .collect();
        for rx in rxs {
            assert!(rx.recv().unwrap().objective.is_ok());
        }
        let snap = coord.metrics();
        assert_eq!(snap.completed, jobs as u64);
        assert_eq!(snap.warm_hits + snap.warm_misses, jobs as u64);
        assert_eq!(snap.warm_misses, 1, "one build, then warm: {snap}");
        assert!(
            snap.warm_hit_rate() >= 0.9,
            "warm-hit rate {:.2} below bar\n{snap}",
            snap.warm_hit_rate()
        );
        coord.shutdown();
    }

    #[test]
    fn batched_execution_matches_individual_results() {
        // The same job submitted twice (batched on one worker) and
        // once alone must produce identical objectives.
        let mut cfg = test_cfg();
        cfg.native_workers = 1;
        let coord = Coordinator::start(cfg).unwrap();
        let payload = gw_payload(16, 77);
        let a = coord.submit_and_wait(payload.clone()).unwrap();
        let rx1 = coord.submit(payload.clone()).unwrap().1;
        let rx2 = coord.submit(payload.clone()).unwrap().1;
        let b = rx1.recv().unwrap();
        let c = rx2.recv().unwrap();
        let oa = a.objective.unwrap();
        assert_eq!(oa, b.objective.unwrap());
        assert_eq!(oa, c.objective.unwrap());
        coord.shutdown();
    }

    #[test]
    fn dense_jobs_solve_and_count_per_backend() {
        let coord = Coordinator::start(test_cfg()).unwrap();
        let mut rng = Rng::seeded(4);
        let n = 12;
        // A smooth dense geometry (squared distances: exact rank 3).
        let d = crate::grid::dense_dist_1d(&crate::grid::Grid1d::unit(n), 2);
        let payload = JobPayload::gw_dense(
            d.clone(),
            d,
            random_distribution(&mut rng, n),
            random_distribution(&mut rng, n),
            0.05,
        );
        // Small dense → naive under auto-selection.
        let res = coord.submit_and_wait(payload.clone()).unwrap();
        assert!(res.objective.is_ok(), "{:?}", res.objective);
        assert_eq!(res.backend, BackendChoice::NativeNaive);
        assert_eq!(coord.metrics().native_naive, 1);
        coord.shutdown();

        // Forcing lowrank runs the same job on the factored backend
        // and the metrics snapshot records it.
        let mut cfg = test_cfg();
        cfg.policy = RoutingPolicy::Force(crate::gw::GradientKind::LowRank);
        let coord = Coordinator::start(cfg).unwrap();
        let res = coord.submit_and_wait(payload).unwrap();
        assert!(res.objective.is_ok(), "{:?}", res.objective);
        assert_eq!(res.backend, BackendChoice::NativeLowRank);
        assert_eq!(coord.metrics().native_lowrank, 1);
        coord.shutdown();
    }

    #[test]
    fn baseline_policy_routes_naive() {
        let mut cfg = test_cfg();
        cfg.policy = RoutingPolicy::BaselineOnly;
        let coord = Coordinator::start(cfg).unwrap();
        let res = coord.submit_and_wait(gw_payload(10, 3)).unwrap();
        assert_eq!(res.backend, BackendChoice::NativeNaive);
        coord.shutdown();
    }

    #[test]
    fn split_same_geometry_partitions_dense_by_fingerprint() {
        let mk = |scale: f64, id: u64| {
            let d = Mat::from_fn(4, 4, |i, j| scale * ((i as f64) - (j as f64)).abs());
            JobRequest {
                id,
                payload: JobPayload::gw_dense(d.clone(), d, vec![0.25; 4], vec![0.25; 4], 0.05),
                backend: BackendChoice::NativeNaive,
                submitted_at: Instant::now(),
                options: JobOptions::default(),
            }
        };
        let groups = split_same_geometry(vec![mk(1.0, 1), mk(2.0, 2), mk(1.0, 3)]);
        assert_eq!(groups.len(), 2);
        assert_eq!(
            groups[0].iter().map(|j| j.id).collect::<Vec<_>>(),
            vec![1, 3]
        );
        assert_eq!(groups[1][0].id, 2);
    }

    #[test]
    fn split_same_geometry_partitions_mixed_by_support_and_grid() {
        // Mixed jobs group only when both the dense support (by
        // fingerprint + full compare) and the grid descriptor agree.
        let mk = |scale: f64, grid: Geometry, id: u64| {
            let d = Mat::from_fn(4, 4, |i, j| scale * ((i as f64) - (j as f64)).abs());
            let nv = grid.len();
            JobRequest {
                id,
                payload: JobPayload::gw_mixed(
                    d,
                    grid,
                    vec![0.25; 4],
                    vec![1.0 / nv as f64; nv],
                    0.05,
                ),
                backend: BackendChoice::NativeFgc,
                submitted_at: Instant::now(),
                options: JobOptions::default(),
            }
        };
        let g3 = Geometry::grid_3d_unit(2, 1);
        let groups = split_same_geometry(vec![
            mk(1.0, g3.clone(), 1),
            mk(2.0, g3.clone(), 2),
            mk(1.0, g3.clone(), 3),
        ]);
        assert_eq!(groups.len(), 2);
        assert_eq!(
            groups[0].iter().map(|j| j.id).collect::<Vec<_>>(),
            vec![1, 3]
        );
        assert_eq!(groups[1][0].id, 2);
        // Same dense support, different grid spacing: must split (the
        // descriptor compare catches what the u64 key cannot).
        let g3_other = Geometry::grid_3d(2, 0.5, 1);
        let groups = split_same_geometry(vec![mk(1.0, g3, 1), mk(1.0, g3_other, 2)]);
        assert_eq!(groups.len(), 2, "grid spacing must partition");
    }

    #[test]
    fn mixed_fingerprint_collision_still_splits_on_full_compare() {
        // Two mixed payloads with different dense supports but a
        // (forged) equal fingerprint: the collision guard's full
        // matrix compare must keep them apart.
        let mk = |scale: f64, id: u64| {
            let d = Mat::from_fn(4, 4, |i, j| scale * ((i as f64) - (j as f64)).abs());
            JobRequest {
                id,
                payload: JobPayload::GwMixed {
                    dx: d,
                    grid: Geometry::grid_2d_unit(3, 1),
                    u: vec![0.25; 4],
                    v: vec![1.0 / 9.0; 9],
                    epsilon: 0.05,
                    fingerprint: 42,
                },
                backend: BackendChoice::NativeFgc,
                submitted_at: Instant::now(),
                options: JobOptions::default(),
            }
        };
        let groups = split_same_geometry(vec![mk(1.0, 1), mk(2.0, 2)]);
        assert_eq!(groups.len(), 2, "colliding fingerprints must full-compare");
    }

    #[test]
    fn fingerprint_collision_still_splits_on_full_compare() {
        // Two payloads with different matrices but a (forged) equal
        // fingerprint: the collision guard's full matrix compare must
        // keep them apart — a wrong fingerprint costs batching, never
        // correctness.
        let mk = |scale: f64, id: u64| {
            let d = Mat::from_fn(4, 4, |i, j| scale * ((i as f64) - (j as f64)).abs());
            JobRequest {
                id,
                payload: JobPayload::GwDense {
                    dx: d.clone(),
                    dy: d,
                    u: vec![0.25; 4],
                    v: vec![0.25; 4],
                    epsilon: 0.05,
                    fingerprint: 42,
                },
                backend: BackendChoice::NativeNaive,
                submitted_at: Instant::now(),
                options: JobOptions::default(),
            }
        };
        let groups = split_same_geometry(vec![mk(1.0, 1), mk(2.0, 2)]);
        assert_eq!(groups.len(), 2, "colliding fingerprints must full-compare");
    }

    #[test]
    fn dense_rebind_keeps_cache_warm_when_only_dx_changes() {
        // The dense analogue of the mixed-payload rebind: a stream of
        // dense jobs sharing dy but cycling dx must swap the X side in
        // place (one cold build, then warm hits), and a rebound solve
        // must match a fresh coordinator's bit-for-bit.
        let mut rng = Rng::seeded(11);
        let n = 12;
        let dy = crate::grid::dense_dist_1d(&crate::grid::Grid1d::unit(n), 2);
        let dx0 = dy.clone();
        let dx1 = dy.map(|x| 1.5 * x + 0.2);
        let u = random_distribution(&mut rng, n);
        let v = random_distribution(&mut rng, n);
        let job = |dx: &Mat| {
            JobPayload::gw_dense(dx.clone(), dy.clone(), u.clone(), v.clone(), 0.05)
        };

        let mut cfg = test_cfg();
        cfg.native_workers = 1;
        let coord = Coordinator::start(cfg).unwrap();
        let a = coord.submit_and_wait(job(&dx0)).unwrap();
        let b = coord.submit_and_wait(job(&dx1)).unwrap();
        assert!(a.objective.is_ok() && b.objective.is_ok());
        let snap = coord.metrics();
        assert_eq!(
            (snap.warm_misses, snap.warm_hits),
            (1, 1),
            "second dense support must rebind, not rebuild: {snap}"
        );
        coord.shutdown();

        let fresh = Coordinator::start(test_cfg()).unwrap();
        let f = fresh.submit_and_wait(job(&dx1)).unwrap();
        assert_eq!(
            b.objective.unwrap(),
            f.objective.unwrap(),
            "rebound solve must match a fresh build bit-for-bit"
        );
        fresh.shutdown();
    }

    #[test]
    fn f32_tier_serves_and_tracks_metrics() {
        // Service-wide f32 tier: jobs complete, the objective tracks
        // the pure-f64 coordinator's, and the tier is observable in
        // f32_served / warm_units (an f32 entry charges 1 unit).
        let payload = gw_payload(20, 21);
        let coord64 = Coordinator::start(test_cfg()).unwrap();
        let o64 = coord64
            .submit_and_wait(payload.clone())
            .unwrap()
            .objective
            .unwrap();
        coord64.shutdown();

        let mut cfg = test_cfg();
        cfg.native_workers = 1;
        cfg.precision = Precision::F32Refine;
        let coord32 = Coordinator::start(cfg).unwrap();
        let o32 = coord32
            .submit_and_wait(payload)
            .unwrap()
            .objective
            .unwrap();
        let snap = coord32.metrics();
        assert_eq!(snap.f32_served, 1, "{snap}");
        assert_eq!(snap.warm_units, 1, "f32 entry charges one unit: {snap}");
        assert!(
            (o32 - o64).abs() <= 1e-3 * o64.abs() + 1e-9,
            "f32+refine objective {o32} drifted from f64 {o64}"
        );
        coord32.shutdown();
    }

    #[test]
    fn auto_precision_resolves_small_jobs_to_f64() {
        let mut cfg = test_cfg();
        cfg.precision = Precision::Auto;
        let coord = Coordinator::start(cfg).unwrap();
        let res = coord.submit_and_wait(gw_payload(16, 5)).unwrap();
        assert!(res.objective.is_ok());
        let snap = coord.metrics();
        assert_eq!(
            snap.f32_served, 0,
            "below the serve threshold auto must stay f64: {snap}"
        );
        assert_eq!(snap.warm_units, 2, "f64 entry charges two units: {snap}");
        coord.shutdown();
    }

    #[test]
    fn lowrank_coupling_jobs_serve_through_the_factored_path() {
        // A dense job pinned to CouplingRank::LowRank(r) must solve
        // through the factored workspace: a feasible plan comes back,
        // the warm cache holds a 1-unit entry for it (distinct from
        // the full-rank entry of the same shape), and a repeat job is
        // a warm hit on that entry.
        let mut cfg = test_cfg();
        cfg.native_workers = 1;
        let coord = Coordinator::start(cfg).unwrap();
        let mut rng = Rng::seeded(31);
        let n = 14;
        let d = crate::grid::dense_dist_1d(&crate::grid::Grid1d::unit(n), 2);
        let u = random_distribution(&mut rng, n);
        let v = random_distribution(&mut rng, n);
        let payload = JobPayload::gw_dense(d.clone(), d, u.clone(), v.clone(), 0.05);
        let full = coord.submit_and_wait(payload.clone()).unwrap();
        let full_obj = full.objective.unwrap();

        let lr_opts = JobOptions {
            coupling: Some(CouplingRank::LowRank(4)),
            ..JobOptions::default()
        };
        let (_, rx) = coord.submit_with_options(payload.clone(), lr_opts).unwrap();
        let lr = rx.recv().unwrap();
        let lr_obj = lr.objective.unwrap();
        assert!(lr_obj.is_finite());
        let plan = lr.plan.expect("factored solves still return a plan");
        let viol = crate::sinkhorn::marginal_violation(&plan, &u, &v);
        assert!(viol < 1e-5, "factored plan violation {viol:e}");
        // Same entropic-GW problem, different coupling representation:
        // the objectives agree loosely (the rank-dependent gap is
        // pinned tightly in tests/coupling_lowrank.rs).
        assert!(
            (lr_obj - full_obj).abs() <= 0.5 * full_obj.abs() + 1e-2,
            "lowrank {lr_obj} vs full {full_obj}"
        );

        let (_, rx) = coord.submit_with_options(payload, lr_opts).unwrap();
        assert!(rx.recv().unwrap().objective.is_ok());
        let snap = coord.metrics();
        // One full-rank build, one factored build, one factored hit.
        assert_eq!((snap.warm_misses, snap.warm_hits), (2, 1), "{snap}");
        assert_eq!(
            snap.warm_units, 3,
            "full entry charges 2 units, factored entry 1: {snap}"
        );
        coord.shutdown();
    }

    #[test]
    fn auto_coupling_resolves_small_jobs_to_full_rank() {
        // Below the cost model's size threshold, auto (the service
        // default) must keep jobs on the full-rank path — observable
        // through the warm-unit charge (a factored entry would be 1).
        let mut cfg = test_cfg();
        cfg.native_workers = 1;
        assert!(cfg.coupling.is_none(), "service default is auto");
        let coord = Coordinator::start(cfg).unwrap();
        let res = coord.submit_and_wait(gw_payload(16, 6)).unwrap();
        assert!(res.objective.is_ok());
        let snap = coord.metrics();
        assert_eq!(snap.warm_units, 2, "small jobs stay full-rank: {snap}");
        coord.shutdown();
    }

    #[test]
    fn ladder_climbs_rungs_in_order_within_budget() {
        let metrics = ServiceMetrics::new();
        let grid = JobRequest {
            id: 1,
            payload: gw_payload(8, 1),
            backend: BackendChoice::NativeFgc,
            submitted_at: Instant::now(),
            options: JobOptions::default(),
        };
        let mut ov = SolveOverrides {
            force_log: false,
            epsilon_scale: 1.0,
            kind_override: None,
        };
        let mut rung = 0u32;
        assert!(climb(&mut rung, &mut ov, &grid, &metrics));
        assert!(ov.force_log);
        assert!(climb(&mut rung, &mut ov, &grid, &metrics));
        assert!(ov.epsilon_scale == 2.0);
        // Grid payloads have no exact backend fallback: the ladder ends.
        assert!(!climb(&mut rung, &mut ov, &grid, &metrics));
        let snap = metrics.snapshot();
        assert_eq!(
            (snap.retries_regime, snap.retries_anneal, snap.retries_backend),
            (1, 1, 0)
        );
    }

    #[test]
    fn ladder_backend_rung_needs_dense_lowrank_and_budget() {
        let metrics = ServiceMetrics::new();
        let d = crate::grid::dense_dist_1d(&crate::grid::Grid1d::unit(6), 2);
        let mut dense = JobRequest {
            id: 1,
            payload: JobPayload::gw_dense(
                d.clone(),
                d,
                vec![1.0 / 6.0; 6],
                vec![1.0 / 6.0; 6],
                0.05,
            ),
            backend: BackendChoice::NativeLowRank,
            submitted_at: Instant::now(),
            options: JobOptions::default(),
        };
        let mut ov = SolveOverrides {
            force_log: false,
            epsilon_scale: 1.0,
            kind_override: None,
        };
        let mut rung = 0u32;
        assert!(climb(&mut rung, &mut ov, &dense, &metrics));
        assert!(climb(&mut rung, &mut ov, &dense, &metrics));
        assert!(
            climb(&mut rung, &mut ov, &dense, &metrics),
            "lowrank dense gets the backend rung"
        );
        assert_eq!(ov.kind_override, Some(GradientKind::Naive));
        assert!(
            ov.epsilon_scale == 1.0,
            "backend rung retries at the job's own ε"
        );
        assert!(
            !climb(&mut rung, &mut ov, &dense, &metrics),
            "no rung past the backend swap"
        );
        // A zero retry budget never enters the ladder at all.
        dense.options.max_retries = 0;
        let mut rung = 0u32;
        assert!(!climb(&mut rung, &mut ov, &dense, &metrics));
    }

    fn cloud(rng: &mut Rng, n: usize, dim: usize) -> Mat {
        Mat::from_fn(n, dim, |_, _| rng.uniform_in(-1.0, 1.0))
    }

    fn screen_payload(seed: u64, k: usize, top_k: usize, slices: usize) -> JobPayload {
        let mut rng = Rng::seeded(seed);
        let query = cloud(&mut rng, 10, 2);
        let candidates: Vec<Mat> = (0..k).map(|_| cloud(&mut rng, 8, 2)).collect();
        JobPayload::gw_screen(query, candidates, top_k, slices, false, 0.05)
    }

    #[test]
    fn screen_jobs_round_trip_and_match_direct_solves() {
        let cfg = test_cfg();
        let coord = Coordinator::start(cfg.clone()).unwrap();
        let payload = screen_payload(11, 5, 2, 16);
        let res = coord.submit_and_wait(payload.clone()).unwrap();
        assert!(res.objective.is_ok(), "{:?}", res.objective);
        assert!(res.plan.is_some());
        // Small unstructured escalation pairs route naive.
        assert_eq!(res.backend, BackendChoice::NativeNaive);
        let outcome = res.screen.as_ref().expect("screen jobs report an outcome");
        assert_eq!(outcome.scores.len(), 5);
        assert_eq!(outcome.hits.len(), 2);
        assert_eq!(outcome.slices, 16);
        assert!(
            outcome.hits[0].objective <= outcome.hits[1].objective,
            "hits sorted best-first: {outcome:?}"
        );
        assert_eq!(
            res.objective.as_ref().unwrap().to_bits(),
            outcome.hits[0].objective.to_bits(),
            "result objective is the best escalated hit"
        );
        let snap = coord.metrics();
        assert_eq!((snap.screened, snap.escalated), (5, 2));
        coord.shutdown();

        // The service path is bit-for-bit the library path: same seed,
        // same slice count, same solver configuration, same backend.
        let JobPayload::GwScreen {
            query, candidates, ..
        } = &payload
        else {
            unreachable!()
        };
        let mut ws = SlicedWorkspace::with_default_seed();
        let scfg = SlicedConfig {
            slices: 16,
            threads: cfg.solver_threads,
            ..SlicedConfig::default()
        };
        ws.screen_into(query, candidates, &scfg).unwrap();
        for (service, direct) in outcome.scores.iter().zip(ws.scores()) {
            assert_eq!(service.to_bits(), direct.to_bits());
        }
        let hits = ws
            .escalate(
                query,
                candidates,
                2,
                &gw_cfg(&cfg, 0.05, Precision::F64),
                GradientKind::Naive,
                false,
                None,
            )
            .unwrap();
        for (service, direct) in outcome.hits.iter().zip(&hits) {
            assert_eq!(service.candidate, direct.candidate);
            assert_eq!(
                service.objective.to_bits(),
                direct.solution.objective.to_bits()
            );
        }
        assert_eq!(
            res.plan.as_ref().unwrap().as_slice(),
            hits[0].solution.plan.as_slice(),
            "plan of the best hit matches the direct solve bit-for-bit"
        );
    }

    #[test]
    fn screen_warm_cache_reuses_workspace() {
        let mut cfg = test_cfg();
        cfg.native_workers = 1;
        let coord = Coordinator::start(cfg).unwrap();
        let a = coord.submit_and_wait(screen_payload(21, 4, 1, 12)).unwrap();
        let b = coord.submit_and_wait(screen_payload(22, 4, 1, 12)).unwrap();
        assert!(a.objective.is_ok() && b.objective.is_ok());
        let snap = coord.metrics();
        assert_eq!(snap.warm_misses, 1, "one build, then warm: {snap}");
        assert_eq!(snap.warm_hits, 1, "{snap}");
        assert_eq!(snap.warm_units, 1, "screen entries charge one unit: {snap}");
        assert_eq!((snap.screened, snap.escalated), (8, 2));
        coord.shutdown();
    }

    #[test]
    fn screen_policy_picks_slices_from_deadline_budget() {
        // No explicit slice count + a generous deadline: the policy
        // chooses, and the outcome reports what it chose.
        let coord = Coordinator::start(test_cfg()).unwrap();
        let opts = JobOptions {
            deadline: Some(Duration::from_secs(30)),
            ..JobOptions::default()
        };
        let (_, rx) = coord
            .submit_with_options(screen_payload(31, 3, 1, 0), opts)
            .unwrap();
        let res = rx.recv().unwrap();
        assert!(res.objective.is_ok(), "{:?}", res.objective);
        let outcome = res.screen.unwrap();
        let expected = crate::gw::backend::cost_model::screen_slices(
            10,
            3 * 8,
            Duration::from_secs(30),
        );
        assert_eq!(outcome.slices, expected);
        coord.shutdown();
    }
}
