//! The coordinator service: admission → routing → bounded queues →
//! worker pool → results + metrics.

use super::batcher::group_by_variant;
use super::job::{BackendChoice, JobId, JobPayload, JobRequest, JobResult};
use super::metrics::{MetricsSnapshot, ServiceMetrics};
use super::queue::BoundedQueue;
use super::router::{Router, RoutingPolicy};
use crate::error::{Error, Result};
use crate::gw::{EntropicGw, Geometry, GwConfig};
use crate::runtime::{ArtifactRegistry, Executor};
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Service configuration.
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    /// Native compute threads.
    pub native_workers: usize,
    /// Bounded queue capacity (admission backpressure threshold).
    pub queue_capacity: usize,
    /// Max jobs drained per batch.
    pub batch_max: usize,
    /// Artifact directory (`manifest.txt` inside).
    pub artifacts_dir: PathBuf,
    /// Routing policy.
    pub policy: RoutingPolicy,
    /// Spawn the PJRT worker (requires artifacts + libxla at runtime).
    pub enable_pjrt: bool,
    /// Mirror-descent outer iterations for native solves.
    pub outer_iters: usize,
    /// Inner Sinkhorn cap for native solves.
    pub sinkhorn_max_iters: usize,
    /// Inner Sinkhorn tolerance.
    pub sinkhorn_tolerance: f64,
    /// Per-job thread budget for the solver's hot kernels (`1` =
    /// serial; `0` = all cores — use with `native_workers = 1` to
    /// avoid oversubscription, the budgets multiply).
    pub solver_threads: usize,
    /// How long `submit` may block under backpressure.
    pub submit_timeout: Duration,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            native_workers: 2,
            queue_capacity: 64,
            batch_max: 8,
            artifacts_dir: PathBuf::from("artifacts"),
            policy: RoutingPolicy::PreferPjrt,
            enable_pjrt: false,
            outer_iters: 10,
            sinkhorn_max_iters: 1000,
            sinkhorn_tolerance: 1e-9,
            solver_threads: 1,
            submit_timeout: Duration::from_millis(200),
        }
    }
}

type Envelope = (JobRequest, mpsc::Sender<JobResult>);

/// Running service handle.
pub struct Coordinator {
    cfg: CoordinatorConfig,
    router: Router,
    native_q: BoundedQueue<Envelope>,
    pjrt_q: Option<BoundedQueue<Envelope>>,
    metrics: Arc<ServiceMetrics>,
    workers: Vec<JoinHandle<()>>,
    next_id: AtomicU64,
}

impl Coordinator {
    /// Load artifacts, spawn workers, return the handle.
    pub fn start(cfg: CoordinatorConfig) -> Result<Self> {
        let registry = ArtifactRegistry::load(&cfg.artifacts_dir)?;
        let effective_policy = if cfg.enable_pjrt {
            cfg.policy
        } else {
            // Without a PJRT worker, artifact routes would strand jobs.
            match cfg.policy {
                RoutingPolicy::PreferPjrt => RoutingPolicy::NativeOnly,
                p => p,
            }
        };
        let router = Router::new(registry, effective_policy);
        let native_q: BoundedQueue<Envelope> = BoundedQueue::new(cfg.queue_capacity);
        let metrics = Arc::new(ServiceMetrics::new());
        let mut workers = Vec::new();

        for wid in 0..cfg.native_workers.max(1) {
            let q = native_q.clone();
            let m = Arc::clone(&metrics);
            let wcfg = cfg.clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("fgcgw-native-{wid}"))
                    .spawn(move || native_worker_loop(q, m, wcfg))
                    .map_err(|e| Error::Runtime(format!("spawn worker: {e}")))?,
            );
        }

        let pjrt_q = if cfg.enable_pjrt {
            let q: BoundedQueue<Envelope> = BoundedQueue::new(cfg.queue_capacity);
            let q2 = q.clone();
            let m = Arc::clone(&metrics);
            let wcfg = cfg.clone();
            let registry2 = router.registry().clone();
            workers.push(
                std::thread::Builder::new()
                    .name("fgcgw-pjrt".into())
                    .spawn(move || pjrt_worker_loop(q2, m, wcfg, registry2))
                    .map_err(|e| Error::Runtime(format!("spawn pjrt worker: {e}")))?,
            );
            Some(q)
        } else {
            None
        };

        Ok(Coordinator {
            cfg,
            router,
            native_q,
            pjrt_q,
            metrics,
            workers,
            next_id: AtomicU64::new(1),
        })
    }

    /// The router (inspection / tests).
    pub fn router(&self) -> &Router {
        &self.router
    }

    /// Submit a job; returns its id and the result channel. Rejects on
    /// invalid payloads and on backpressure timeout.
    pub fn submit(&self, payload: JobPayload) -> Result<(JobId, mpsc::Receiver<JobResult>)> {
        if let Err(msg) = payload.validate() {
            self.metrics.on_reject();
            return Err(Error::Rejected(format!("validation: {msg}")));
        }
        let backend = self.router.route(&payload);
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = mpsc::channel();
        let req = JobRequest {
            id,
            payload,
            backend: backend.clone(),
            submitted_at: Instant::now(),
        };
        let queue = match (&backend, &self.pjrt_q) {
            (BackendChoice::Pjrt(_), Some(q)) => q,
            _ => &self.native_q,
        };
        match queue.push_timeout((req, tx), self.cfg.submit_timeout) {
            Ok(()) => {
                self.metrics.on_submit();
                Ok((id, rx))
            }
            Err(e) => {
                self.metrics.on_reject();
                Err(e)
            }
        }
    }

    /// Convenience: submit and wait for the result.
    pub fn submit_and_wait(&self, payload: JobPayload) -> Result<JobResult> {
        let (_, rx) = self.submit(payload)?;
        rx.recv()
            .map_err(|_| Error::Runtime("worker dropped result channel".into()))
    }

    /// Current metrics.
    pub fn metrics(&self) -> MetricsSnapshot {
        self.metrics.snapshot()
    }

    /// Graceful shutdown: close queues, join workers.
    pub fn shutdown(self) {
        self.native_q.close();
        if let Some(q) = &self.pjrt_q {
            q.close();
        }
        for w in self.workers {
            let _ = w.join();
        }
    }
}

// ---------------------------------------------------------------------------
// Workers
// ---------------------------------------------------------------------------

fn native_worker_loop(
    q: BoundedQueue<Envelope>,
    metrics: Arc<ServiceMetrics>,
    cfg: CoordinatorConfig,
) {
    while let Some(first) = q.pop() {
        // Drain a batch and group by variant so same-shape jobs run
        // back-to-back (warm caches/workspaces).
        let mut batch = vec![first];
        batch.extend(q.pop_batch(cfg.batch_max.saturating_sub(1)));
        let (reqs, txs): (Vec<JobRequest>, Vec<mpsc::Sender<JobResult>>) =
            batch.into_iter().unzip();
        let mut tx_by_id: std::collections::HashMap<JobId, mpsc::Sender<JobResult>> = reqs
            .iter()
            .map(|r| r.id)
            .zip(txs)
            .collect();
        for (_variant, jobs) in group_by_variant(reqs) {
            for req in jobs {
                let tx = tx_by_id.remove(&req.id).expect("sender registered");
                let result = execute_native(&req, &cfg);
                report(&metrics, &result);
                let _ = tx.send(result);
            }
        }
    }
}

fn pjrt_worker_loop(
    q: BoundedQueue<Envelope>,
    metrics: Arc<ServiceMetrics>,
    cfg: CoordinatorConfig,
    registry: ArtifactRegistry,
) {
    let mut executor = match Executor::cpu() {
        Ok(e) => Some(e),
        Err(e) => {
            eprintln!("[fgcgw] PJRT unavailable ({e}); falling back to native");
            None
        }
    };
    while let Some((req, tx)) = q.pop() {
        let started = Instant::now();
        let result = match (&req.backend, executor.as_mut()) {
            (BackendChoice::Pjrt(name), Some(ex)) => {
                match execute_pjrt(ex, &registry, name, &req) {
                    Ok(r) => r,
                    Err(e) => {
                        // Artifact failure → native fallback keeps the
                        // job alive; record the downgraded backend.
                        eprintln!("[fgcgw] pjrt {name} failed ({e}); native fallback");
                        let mut r = execute_native(&req, &cfg);
                        r.backend = BackendChoice::NativeFgc;
                        r
                    }
                }
            }
            _ => {
                // Executor unavailable: the job runs natively, so the
                // result (and the per-backend metrics) must say so.
                let mut r = execute_native(&req, &cfg);
                if matches!(req.backend, BackendChoice::Pjrt(_)) {
                    r.backend = BackendChoice::NativeFgc;
                }
                r
            }
        };
        let _ = started;
        report(&metrics, &result);
        let _ = tx.send(result);
    }
}

fn report(metrics: &ServiceMetrics, result: &JobResult) {
    // Count the backend that actually ran (PJRT failures downgrade to
    // native in `result.backend`).
    metrics.on_complete(
        &result.backend,
        result.objective.is_ok(),
        result.queue_time,
        result.solve_time,
    );
}

/// Run a job on the native solvers.
fn execute_native(req: &JobRequest, cfg: &CoordinatorConfig) -> JobResult {
    let queue_time = req.submitted_at.elapsed();
    let kind = req.backend.gradient_kind();
    let started = Instant::now();
    let solved: Result<(crate::linalg::Mat, f64)> = (|| {
        match &req.payload {
            JobPayload::Gw1d { u, v, k, epsilon } => {
                let solver = EntropicGw::grid_1d(u.len(), v.len(), *k, gw_cfg(cfg, *epsilon));
                let sol = solver.solve(u, v, kind)?;
                Ok((sol.plan, sol.objective))
            }
            JobPayload::Fgw1d {
                u,
                v,
                feature_cost,
                theta,
                k,
                epsilon,
            } => {
                let solver = EntropicGw::grid_1d(u.len(), v.len(), *k, gw_cfg(cfg, *epsilon));
                let sol = solver.solve_fgw(u, v, feature_cost, *theta, kind)?;
                Ok((sol.plan, sol.objective))
            }
            JobPayload::Gw2d { n, u, v, k, epsilon } => {
                let solver = EntropicGw::new(
                    Geometry::grid_2d_unit(*n, *k),
                    Geometry::grid_2d_unit(*n, *k),
                    gw_cfg(cfg, *epsilon),
                );
                let sol = solver.solve(u, v, kind)?;
                Ok((sol.plan, sol.objective))
            }
            JobPayload::GwDense {
                dx,
                dy,
                u,
                v,
                epsilon,
            } => {
                let solver = EntropicGw::new(
                    Geometry::Dense(dx.clone()),
                    Geometry::Dense(dy.clone()),
                    gw_cfg(cfg, *epsilon),
                );
                let sol = solver.solve(u, v, kind)?;
                Ok((sol.plan, sol.objective))
            }
        }
    })();
    let solve_time = started.elapsed();
    match solved {
        Ok((plan, obj)) => JobResult {
            id: req.id,
            objective: Ok(obj),
            plan: Some(plan),
            backend: req.backend.clone(),
            queue_time,
            solve_time,
        },
        Err(e) => JobResult {
            id: req.id,
            objective: Err(e.to_string()),
            plan: None,
            backend: req.backend.clone(),
            queue_time,
            solve_time,
        },
    }
}

/// Run a job through a compiled artifact.
fn execute_pjrt(
    executor: &mut Executor,
    registry: &ArtifactRegistry,
    name: &str,
    req: &JobRequest,
) -> Result<JobResult> {
    let queue_time = req.submitted_at.elapsed();
    let spec = registry
        .by_name(name)
        .ok_or_else(|| Error::ArtifactNotFound(name.to_string()))?;
    let started = Instant::now();
    let out = match &req.payload {
        JobPayload::Gw1d { u, v, .. } | JobPayload::Gw2d { u, v, .. } => {
            executor.run_gw_solve(spec, u, v)?
        }
        JobPayload::Fgw1d {
            u, v, feature_cost, ..
        } => executor.run_fgw_solve(spec, u, v, feature_cost)?,
        // The router never assigns dense jobs to PJRT (no artifacts
        // exist for unstructured geometries).
        JobPayload::GwDense { .. } => {
            return Err(Error::Runtime(
                "no PJRT artifact family for dense-geometry jobs".into(),
            ))
        }
    };
    Ok(JobResult {
        id: req.id,
        objective: Ok(out.objective),
        plan: Some(out.plan),
        backend: req.backend.clone(),
        queue_time,
        solve_time: started.elapsed(),
    })
}

fn gw_cfg(cfg: &CoordinatorConfig, epsilon: f64) -> GwConfig {
    GwConfig {
        epsilon,
        outer_iters: cfg.outer_iters,
        sinkhorn_max_iters: cfg.sinkhorn_max_iters,
        sinkhorn_tolerance: cfg.sinkhorn_tolerance,
        sinkhorn_check_every: 10,
        threads: cfg.solver_threads,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::random_distribution;
    use crate::prng::Rng;

    fn test_cfg() -> CoordinatorConfig {
        CoordinatorConfig {
            native_workers: 2,
            queue_capacity: 16,
            batch_max: 4,
            artifacts_dir: PathBuf::from("/nonexistent"),
            policy: RoutingPolicy::PreferPjrt,
            enable_pjrt: false,
            outer_iters: 5,
            sinkhorn_max_iters: 300,
            sinkhorn_tolerance: 1e-8,
            solver_threads: 2,
            submit_timeout: Duration::from_millis(100),
        }
    }

    fn gw_payload(n: usize, seed: u64) -> JobPayload {
        let mut rng = Rng::seeded(seed);
        JobPayload::Gw1d {
            u: random_distribution(&mut rng, n),
            v: random_distribution(&mut rng, n),
            k: 1,
            epsilon: 0.01,
        }
    }

    #[test]
    fn end_to_end_native_solve() {
        let coord = Coordinator::start(test_cfg()).unwrap();
        let res = coord.submit_and_wait(gw_payload(20, 1)).unwrap();
        assert!(res.objective.is_ok());
        assert!(res.plan.is_some());
        assert_eq!(res.backend, BackendChoice::NativeFgc);
        let snap = coord.metrics();
        assert_eq!(snap.completed, 1);
        coord.shutdown();
    }

    #[test]
    fn many_jobs_all_complete() {
        let coord = Coordinator::start(test_cfg()).unwrap();
        let rxs: Vec<_> = (0..10)
            .map(|i| coord.submit(gw_payload(12 + (i % 3), 100 + i as u64)).unwrap().1)
            .collect();
        for rx in rxs {
            let res = rx.recv().unwrap();
            assert!(res.objective.is_ok(), "{:?}", res.objective);
        }
        assert_eq!(coord.metrics().completed, 10);
        coord.shutdown();
    }

    #[test]
    fn invalid_payload_rejected_at_admission() {
        let coord = Coordinator::start(test_cfg()).unwrap();
        let bad = JobPayload::Gw1d {
            u: vec![0.7, 0.7],
            v: vec![0.5, 0.5],
            k: 1,
            epsilon: 0.01,
        };
        assert!(coord.submit(bad).is_err());
        assert_eq!(coord.metrics().rejected, 1);
        coord.shutdown();
    }

    #[test]
    fn shutdown_is_clean_with_pending_results() {
        let coord = Coordinator::start(test_cfg()).unwrap();
        let (_, rx) = coord.submit(gw_payload(16, 9)).unwrap();
        coord.shutdown(); // workers drain before exiting
        assert!(rx.recv().unwrap().objective.is_ok());
    }

    #[test]
    fn dense_jobs_solve_and_count_per_backend() {
        let coord = Coordinator::start(test_cfg()).unwrap();
        let mut rng = Rng::seeded(4);
        let n = 12;
        // A smooth dense geometry (squared distances: exact rank 3).
        let d = crate::grid::dense_dist_1d(&crate::grid::Grid1d::unit(n), 2);
        let payload = JobPayload::GwDense {
            dx: d.clone(),
            dy: d,
            u: random_distribution(&mut rng, n),
            v: random_distribution(&mut rng, n),
            epsilon: 0.05,
        };
        // Small dense → naive under auto-selection.
        let res = coord.submit_and_wait(payload.clone()).unwrap();
        assert!(res.objective.is_ok(), "{:?}", res.objective);
        assert_eq!(res.backend, BackendChoice::NativeNaive);
        assert_eq!(coord.metrics().native_naive, 1);
        coord.shutdown();

        // Forcing lowrank runs the same job on the factored backend
        // and the metrics snapshot records it.
        let mut cfg = test_cfg();
        cfg.policy = RoutingPolicy::Force(crate::gw::GradientKind::LowRank);
        let coord = Coordinator::start(cfg).unwrap();
        let res = coord.submit_and_wait(payload).unwrap();
        assert!(res.objective.is_ok(), "{:?}", res.objective);
        assert_eq!(res.backend, BackendChoice::NativeLowRank);
        assert_eq!(coord.metrics().native_lowrank, 1);
        coord.shutdown();
    }

    #[test]
    fn baseline_policy_routes_naive() {
        let mut cfg = test_cfg();
        cfg.policy = RoutingPolicy::BaselineOnly;
        let coord = Coordinator::start(cfg).unwrap();
        let res = coord.submit_and_wait(gw_payload(10, 3)).unwrap();
        assert_eq!(res.backend, BackendChoice::NativeNaive);
        coord.shutdown();
    }
}
