//! Hand-rolled CLI argument parsing (clap is not in the offline crate
//! set). Supports `command [positional…] [--flag] [--key value]`.

use crate::error::{Error, Result};
use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    /// First non-flag token (subcommand).
    pub command: Option<String>,
    /// Remaining positionals.
    pub positional: Vec<String>,
    /// `--key value` and `--key=value` options.
    options: BTreeMap<String, String>,
    /// Bare `--flag`s.
    flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of tokens (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(tokens: I) -> Result<Self> {
        let mut out = Args::default();
        let mut it = tokens.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                if stripped.is_empty() {
                    return Err(Error::Config("bare `--` not supported".into()));
                }
                if let Some((k, v)) = stripped.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.options.insert(stripped.to_string(), v);
                } else {
                    out.flags.push(stripped.to_string());
                }
            } else if out.command.is_none() {
                out.command = Some(tok);
            } else {
                out.positional.push(tok);
            }
        }
        Ok(out)
    }

    /// Parse the process arguments.
    pub fn from_env() -> Result<Self> {
        Self::parse(std::env::args().skip(1))
    }

    /// Option lookup with typed default.
    pub fn get_or<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T> {
        match self.options.get(key) {
            None => Ok(default),
            Some(raw) => raw
                .parse()
                .map_err(|_| Error::Config(format!("--{key}: cannot parse `{raw}`"))),
        }
    }

    /// Typed lookup that distinguishes "absent" from a given value —
    /// used for CLI overrides that should defer to a config file when
    /// the flag is not passed (e.g. `--threads`).
    pub fn get_opt<T: std::str::FromStr>(&self, key: &str) -> Result<Option<T>> {
        match self.options.get(key) {
            None => Ok(None),
            Some(raw) => raw
                .parse()
                .map(Some)
                .map_err(|_| Error::Config(format!("--{key}: cannot parse `{raw}`"))),
        }
    }

    /// Raw option value.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    /// Was `--flag` given (as a bare flag)?
    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// Comma-separated list option.
    pub fn get_list_or(&self, key: &str, default: &[usize]) -> Result<Vec<usize>> {
        match self.options.get(key) {
            None => Ok(default.to_vec()),
            Some(raw) => raw
                .split(',')
                .filter(|s| !s.is_empty())
                .map(|s| {
                    s.trim()
                        .parse()
                        .map_err(|_| Error::Config(format!("--{key}: bad entry `{s}`")))
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(tokens: &[&str]) -> Args {
        Args::parse(tokens.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn command_positionals_options_flags() {
        let a = parse(&["solve", "--n", "500", "input.dat", "--verbose", "--eps=0.002"]);
        assert_eq!(a.command.as_deref(), Some("solve"));
        assert_eq!(a.positional, vec!["input.dat"]);
        assert_eq!(a.get_or("n", 0usize).unwrap(), 500);
        assert_eq!(a.get_or("eps", 0.0f64).unwrap(), 0.002);
        assert!(a.has_flag("verbose"));
        assert!(!a.has_flag("quiet"));
    }

    #[test]
    fn typed_defaults_and_errors() {
        let a = parse(&["x", "--bad", "zzz"]);
        assert_eq!(a.get_or("missing", 7u32).unwrap(), 7);
        assert!(a.get_or("bad", 0u32).is_err());
    }

    #[test]
    fn optional_lookup_distinguishes_absent() {
        let a = parse(&["solve", "--threads", "4"]);
        assert_eq!(a.get_opt::<usize>("threads").unwrap(), Some(4));
        assert_eq!(a.get_opt::<usize>("workers").unwrap(), None);
        let b = parse(&["solve", "--threads", "x"]);
        assert!(b.get_opt::<usize>("threads").is_err());
    }

    #[test]
    fn list_option() {
        let a = parse(&["bench", "--sizes", "500,1000,2000"]);
        assert_eq!(a.get_list_or("sizes", &[1]).unwrap(), vec![500, 1000, 2000]);
        assert_eq!(a.get_list_or("other", &[4, 5]).unwrap(), vec![4, 5]);
    }

    #[test]
    fn trailing_flag_without_value() {
        let a = parse(&["run", "--fast"]);
        assert!(a.has_flag("fast"));
    }
}
