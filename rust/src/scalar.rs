//! Precision-generic scalar abstraction for the hot kernels.
//!
//! The FGC scans, the Sinkhorn sweeps and the dense row/col multiplies
//! are memory-bound: halving the element width halves the bytes every
//! sweep streams. [`Scalar`] is the minimal surface those kernels need
//! — arithmetic, the literals the fused small-`k` arms use, `exp`/`ln`
//! for the Gibbs/log-domain sweeps, and `f64` conversions at the
//! boundaries (binomial coefficients stay `f64`-tabulated; generic
//! kernels pull them through [`Scalar::from_f64`]).
//!
//! Monomorphized at `T = f64` every generic kernel performs the exact
//! operation sequence of the pre-generic code ([`Scalar::from_f64`] is
//! the identity on `f64`), so the bitwise conformance suites pin the
//! refactor: genericization is a type-level change, not a numeric one.

use std::fmt::Debug;
use std::ops::{Add, AddAssign, Div, DivAssign, Mul, MulAssign, Neg, Sub, SubAssign};

/// Element type of a precision-generic kernel: `f32` or `f64`.
pub trait Scalar:
    Copy
    + Send
    + Sync
    + PartialOrd
    + PartialEq
    + Debug
    + Add<Output = Self>
    + Sub<Output = Self>
    + Mul<Output = Self>
    + Div<Output = Self>
    + Neg<Output = Self>
    + AddAssign
    + SubAssign
    + MulAssign
    + DivAssign
    + 'static
{
    /// Additive identity.
    const ZERO: Self;
    /// Multiplicative identity.
    const ONE: Self;
    /// The literal `2` (the fused `k = 2` scan arm).
    const TWO: Self;

    /// Narrowing (or identity) conversion from `f64`. On `f64` this is
    /// the identity, which is what keeps monomorphized-f64 kernels
    /// bit-for-bit with the pre-generic code.
    fn from_f64(x: f64) -> Self;
    /// Widening (or identity) conversion to `f64`.
    fn to_f64(self) -> f64;
    /// `e^self` (Gibbs kernel build, log-domain plan recovery).
    fn exp(self) -> Self;
    /// Natural log (log-domain potentials).
    fn ln(self) -> Self;
    /// Absolute value.
    fn abs(self) -> Self;
    /// `max` with NaN-propagation semantics of the float primitives.
    fn max_s(self, other: Self) -> Self;
    /// Finite check (the numeric-failure guards).
    fn finite(self) -> bool;
    /// `-∞` (log-sum-exp seeds).
    fn neg_infinity() -> Self;
}

impl Scalar for f64 {
    const ZERO: Self = 0.0;
    const ONE: Self = 1.0;
    const TWO: Self = 2.0;

    #[inline(always)]
    fn from_f64(x: f64) -> Self {
        x
    }
    #[inline(always)]
    fn to_f64(self) -> f64 {
        self
    }
    #[inline(always)]
    fn exp(self) -> Self {
        f64::exp(self)
    }
    #[inline(always)]
    fn ln(self) -> Self {
        f64::ln(self)
    }
    #[inline(always)]
    fn abs(self) -> Self {
        f64::abs(self)
    }
    #[inline(always)]
    fn max_s(self, other: Self) -> Self {
        f64::max(self, other)
    }
    #[inline(always)]
    fn finite(self) -> bool {
        f64::is_finite(self)
    }
    #[inline(always)]
    fn neg_infinity() -> Self {
        f64::NEG_INFINITY
    }
}

impl Scalar for f32 {
    const ZERO: Self = 0.0;
    const ONE: Self = 1.0;
    const TWO: Self = 2.0;

    #[inline(always)]
    fn from_f64(x: f64) -> Self {
        x as f32
    }
    #[inline(always)]
    fn to_f64(self) -> f64 {
        self as f64
    }
    #[inline(always)]
    fn exp(self) -> Self {
        f32::exp(self)
    }
    #[inline(always)]
    fn ln(self) -> Self {
        f32::ln(self)
    }
    #[inline(always)]
    fn abs(self) -> Self {
        f32::abs(self)
    }
    #[inline(always)]
    fn max_s(self, other: Self) -> Self {
        f32::max(self, other)
    }
    #[inline(always)]
    fn finite(self) -> bool {
        f32::is_finite(self)
    }
    #[inline(always)]
    fn neg_infinity() -> Self {
        f32::NEG_INFINITY
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip<T: Scalar>(x: f64) -> f64 {
        T::from_f64(x).to_f64()
    }

    #[test]
    fn f64_conversions_are_identity() {
        for &x in &[0.0, 1.0, -2.5, 1e300, f64::MIN_POSITIVE] {
            assert_eq!(roundtrip::<f64>(x).to_bits(), x.to_bits());
        }
    }

    #[test]
    fn f32_narrows_and_widens() {
        assert_eq!(roundtrip::<f32>(1.5), 1.5);
        // Values past f32 range saturate to infinity (documented: the
        // f32 lane guards with `finite`).
        assert!(!f32::from_f64(1e300).finite());
    }

    #[test]
    fn literals_match_primitives() {
        assert_eq!(f64::TWO, 2.0f64);
        assert_eq!(f32::TWO, 2.0f32);
        assert_eq!(<f64 as Scalar>::ZERO + f64::ONE, 1.0);
    }

    #[test]
    fn ops_delegate_to_primitives() {
        assert_eq!(<f64 as Scalar>::exp(0.0), 1.0);
        assert_eq!(<f32 as Scalar>::ln(1.0), 0.0);
        assert_eq!((-3.0f32).abs(), 3.0);
        assert_eq!(f64::max_s(1.0, 2.0), 2.0);
        assert!(f64::neg_infinity() < f64::MIN);
    }
}
