//! Wire payload schema: JSON submit bodies onto
//! [`JobPayload`]/[`JobOptions`], and [`JobResult`]s back to JSON.
//!
//! Submit body shape:
//!
//! ```json
//! {
//!   "job": {
//!     "type": "gw1d|fgw1d|gw2d|gw3d|gw_dense|gw_mixed|gw_screen",
//!     "epsilon": 0.01,
//!     ... variant fields (distributions as arrays, matrices as
//!         arrays of row arrays, grids as {"dim","n","h","k"}) ...
//!   },
//!   "timeout_ms": 5000,          // optional → JobOptions::deadline
//!   "wait": false,               // true = respond with the result
//!   "max_retries": 3,            // optional ladder budget
//!   "precision": "f64|f32|auto", // optional tier override
//!   "coupling_rank": "auto",     // "auto" | "full" | positive int
//!   "return_plan": false         // include the transport plan
//! }
//! ```
//!
//! Floats are emitted with Rust's shortest-round-trip `Display` and
//! parsed with `str::parse::<f64>`, so a value that crosses the wire
//! restores to identical bits — the loopback tests pin wire results
//! bit-for-bit against the in-process path.

use super::json::{self, Json};
use crate::coordinator::{JobId, JobOptions, JobPayload, JobResult};
use crate::grid::{Grid1d, Grid2d, Grid3d};
use crate::gw::{CouplingRank, Geometry, Precision};
use crate::linalg::Mat;
use std::fmt::Write as _;

/// A decoded `POST /jobs` body.
#[derive(Debug)]
pub struct SubmitRequest {
    /// The work to enqueue.
    pub payload: JobPayload,
    /// Wire timeout; maps onto [`JobOptions::deadline`].
    pub timeout_ms: Option<u64>,
    /// `true` holds the connection until the result (or timeout).
    pub wait: bool,
    /// Degradation-ladder budget override.
    pub max_retries: Option<u32>,
    /// Precision-tier override.
    pub precision: Option<Precision>,
    /// Coupling-rank override (`None` = service default / auto).
    pub coupling: Option<CouplingRank>,
    /// Include the transport plan in the result body.
    pub return_plan: bool,
}

impl SubmitRequest {
    /// The [`JobOptions`] this request resolves to.
    pub fn options(&self) -> JobOptions {
        JobOptions {
            deadline: self.timeout_ms.map(std::time::Duration::from_millis),
            max_retries: self
                .max_retries
                .unwrap_or_else(|| JobOptions::default().max_retries),
            precision: self.precision,
            coupling: self.coupling,
        }
    }
}

/// Parse a submit body. Errors are client-facing messages (the
/// handler wraps them in a `400`).
pub fn parse_submit(body: &[u8]) -> Result<SubmitRequest, String> {
    let text = std::str::from_utf8(body).map_err(|_| "body is not UTF-8".to_string())?;
    let root = Json::parse(text)?;
    let job = root
        .get("job")
        .ok_or_else(|| "missing `job` object".to_string())?;
    let payload = parse_payload(job)?;
    let timeout_ms = match root.get("timeout_ms") {
        None | Some(Json::Null) => None,
        Some(v) => Some(
            v.as_u64()
                .ok_or_else(|| "`timeout_ms` must be a non-negative integer".to_string())?,
        ),
    };
    let wait = match root.get("wait") {
        None | Some(Json::Null) => false,
        Some(v) => v
            .as_bool()
            .ok_or_else(|| "`wait` must be a boolean".to_string())?,
    };
    let return_plan = match root.get("return_plan") {
        None | Some(Json::Null) => false,
        Some(v) => v
            .as_bool()
            .ok_or_else(|| "`return_plan` must be a boolean".to_string())?,
    };
    let max_retries = match root.get("max_retries") {
        None | Some(Json::Null) => None,
        Some(v) => Some(
            v.as_u64()
                .and_then(|r| u32::try_from(r).ok())
                .ok_or_else(|| "`max_retries` must be a small non-negative integer".to_string())?,
        ),
    };
    let precision = match root.get("precision") {
        None | Some(Json::Null) => None,
        Some(v) => {
            let s = v
                .as_str()
                .ok_or_else(|| "`precision` must be \"f64\", \"f32\", or \"auto\"".to_string())?;
            Some(s.parse::<Precision>().map_err(|e| e.to_string())?)
        }
    };
    let coupling = match root.get("coupling_rank") {
        None | Some(Json::Null) => None,
        Some(Json::Str(s)) if s == "auto" => None,
        Some(Json::Str(s)) if s == "full" => Some(CouplingRank::Full),
        Some(v) => match v.as_usize() {
            Some(r) if r > 0 => Some(CouplingRank::LowRank(r)),
            _ => {
                return Err(
                    "`coupling_rank` must be \"auto\", \"full\", or a positive integer".to_string(),
                )
            }
        },
    };
    Ok(SubmitRequest {
        payload,
        timeout_ms,
        wait,
        max_retries,
        precision,
        coupling,
        return_plan,
    })
}

fn parse_payload(job: &Json) -> Result<JobPayload, String> {
    let ty = job
        .get("type")
        .and_then(Json::as_str)
        .ok_or_else(|| "`job.type` must be a string".to_string())?;
    let epsilon = job
        .get("epsilon")
        .and_then(Json::as_f64)
        .ok_or_else(|| "`job.epsilon` must be a number".to_string())?;
    match ty {
        "gw1d" => Ok(JobPayload::Gw1d {
            u: dist(job, "u")?,
            v: dist(job, "v")?,
            k: exponent(job)?,
            epsilon,
        }),
        "fgw1d" => Ok(JobPayload::Fgw1d {
            u: dist(job, "u")?,
            v: dist(job, "v")?,
            feature_cost: matrix(required(job, "feature_cost")?, "job.feature_cost")?,
            theta: job
                .get("theta")
                .and_then(Json::as_f64)
                .ok_or_else(|| "`job.theta` must be a number".to_string())?,
            k: exponent(job)?,
            epsilon,
        }),
        "gw2d" => Ok(JobPayload::Gw2d {
            n: side(job)?,
            u: dist(job, "u")?,
            v: dist(job, "v")?,
            k: exponent(job)?,
            epsilon,
        }),
        "gw3d" => Ok(JobPayload::Gw3d {
            n: side(job)?,
            u: dist(job, "u")?,
            v: dist(job, "v")?,
            k: exponent(job)?,
            epsilon,
        }),
        "gw_dense" => Ok(JobPayload::gw_dense(
            matrix(required(job, "dx")?, "job.dx")?,
            matrix(required(job, "dy")?, "job.dy")?,
            dist(job, "u")?,
            dist(job, "v")?,
            epsilon,
        )),
        "gw_mixed" => Ok(JobPayload::gw_mixed(
            matrix(required(job, "dx")?, "job.dx")?,
            parse_grid(required(job, "grid")?)?,
            dist(job, "u")?,
            dist(job, "v")?,
            epsilon,
        )),
        "gw_screen" => {
            let query = matrix(required(job, "query")?, "job.query")?;
            let candidates = required(job, "candidates")?
                .as_arr()
                .ok_or_else(|| "`job.candidates` must be an array of matrices".to_string())?
                .iter()
                .map(|c| matrix(c, "job.candidates[..]"))
                .collect::<Result<Vec<Mat>, String>>()?;
            let top_k = job
                .get("top_k")
                .and_then(Json::as_usize)
                .ok_or_else(|| "`job.top_k` must be a positive integer".to_string())?;
            let slices = match job.get("slices") {
                None | Some(Json::Null) => 0,
                Some(v) => v
                    .as_usize()
                    .ok_or_else(|| "`job.slices` must be a non-negative integer".to_string())?,
            };
            let warm_start = match job.get("warm_start") {
                None | Some(Json::Null) => false,
                Some(v) => v
                    .as_bool()
                    .ok_or_else(|| "`job.warm_start` must be a boolean".to_string())?,
            };
            Ok(JobPayload::gw_screen(
                query, candidates, top_k, slices, warm_start, epsilon,
            ))
        }
        other => Err(format!("unknown job type `{other}`")),
    }
}

fn required<'a>(job: &'a Json, key: &str) -> Result<&'a Json, String> {
    job.get(key)
        .ok_or_else(|| format!("missing `job.{key}` field"))
}

fn dist(job: &Json, key: &str) -> Result<Vec<f64>, String> {
    let arr = job
        .get(key)
        .and_then(Json::as_arr)
        .ok_or_else(|| format!("`job.{key}` must be an array of numbers"))?;
    arr.iter()
        .map(|v| {
            v.as_f64()
                .ok_or_else(|| format!("`job.{key}` must contain only numbers"))
        })
        .collect()
}

fn matrix(v: &Json, name: &str) -> Result<Mat, String> {
    let rows = v
        .as_arr()
        .ok_or_else(|| format!("`{name}` must be an array of row arrays"))?;
    if rows.is_empty() {
        return Err(format!("`{name}` has no rows"));
    }
    let mut data = Vec::new();
    let mut cols = None;
    for row in rows {
        let row = row
            .as_arr()
            .ok_or_else(|| format!("`{name}` rows must be arrays"))?;
        match cols {
            None => cols = Some(row.len()),
            Some(c) if c == row.len() => {}
            Some(c) => {
                return Err(format!(
                    "`{name}` rows have inconsistent lengths ({c} vs {})",
                    row.len()
                ))
            }
        }
        for x in row {
            data.push(
                x.as_f64()
                    .ok_or_else(|| format!("`{name}` must contain only numbers"))?,
            );
        }
    }
    let cols = cols.unwrap_or(0);
    if cols == 0 {
        return Err(format!("`{name}` has empty rows"));
    }
    Mat::from_vec(rows.len(), cols, data).map_err(|e| e.to_string())
}

/// Build the mixed payload's grid descriptor. The grid structs'
/// `new` constructors assert on degenerate inputs, so this uses the
/// public-field literals and lets [`JobPayload::validate`] reject bad
/// descriptors with a clean `400` instead of panicking a handler.
fn parse_grid(v: &Json) -> Result<Geometry, String> {
    let dim = v
        .get("dim")
        .and_then(Json::as_u64)
        .ok_or_else(|| "`grid.dim` must be 1, 2, or 3".to_string())?;
    let n = v
        .get("n")
        .and_then(Json::as_usize)
        .ok_or_else(|| "`grid.n` must be a positive integer".to_string())?;
    let h = v
        .get("h")
        .and_then(Json::as_f64)
        .ok_or_else(|| "`grid.h` must be a positive number".to_string())?;
    let k = match v.get("k") {
        None | Some(Json::Null) => 1,
        Some(x) => x
            .as_u64()
            .and_then(|k| u32::try_from(k).ok())
            .ok_or_else(|| "`grid.k` must be a small non-negative integer".to_string())?,
    };
    match dim {
        1 => Ok(Geometry::Grid1d {
            grid: Grid1d { n, h },
            k,
        }),
        2 => Ok(Geometry::Grid2d {
            grid: Grid2d { n, h },
            k,
        }),
        3 => Ok(Geometry::Grid3d {
            grid: Grid3d { n, h },
            k,
        }),
        other => Err(format!("`grid.dim` must be 1, 2, or 3, got {other}")),
    }
}

/// Distance exponent: optional, defaults to 1.
fn exponent(job: &Json) -> Result<u32, String> {
    match job.get("k") {
        None | Some(Json::Null) => Ok(1),
        Some(v) => v
            .as_u64()
            .and_then(|k| u32::try_from(k).ok())
            .ok_or_else(|| "`job.k` must be a small non-negative integer".to_string()),
    }
}

fn side(job: &Json) -> Result<usize, String> {
    job.get("n")
        .and_then(Json::as_usize)
        .ok_or_else(|| "`job.n` must be a positive integer".to_string())
}

/// `202 Accepted` body for an async submission.
pub fn encode_queued(id: JobId) -> String {
    format!("{{\"id\":{id},\"status\":\"queued\"}}")
}

/// `202 Accepted` body for a poll that found the job still in flight.
pub fn encode_pending(id: JobId) -> String {
    format!("{{\"id\":{id},\"status\":\"pending\"}}")
}

/// Error body (`{"error": ...}`).
pub fn encode_error(msg: &str) -> String {
    let mut out = String::from("{\"error\":");
    json::write_str(&mut out, msg);
    out.push('}');
    out
}

/// Terminal result body. `return_plan` gates the (possibly large)
/// transport plan; the screening report always rides along when
/// present.
pub fn encode_result(r: &JobResult, return_plan: bool) -> String {
    let mut out = String::with_capacity(256);
    let _ = write!(
        out,
        "{{\"id\":{},\"status\":\"done\",\"ok\":{}",
        r.id,
        r.objective.is_ok()
    );
    match &r.objective {
        Ok(x) => {
            out.push_str(",\"objective\":");
            json::write_f64(&mut out, *x);
        }
        Err(e) => {
            out.push_str(",\"error\":");
            json::write_str(&mut out, e);
        }
    }
    out.push_str(",\"backend\":");
    json::write_str(&mut out, &r.backend.to_string());
    out.push_str(",\"family\":");
    json::write_str(&mut out, r.family);
    let _ = write!(
        out,
        ",\"queue_us\":{},\"solve_us\":{}",
        r.queue_time.as_micros(),
        r.solve_time.as_micros()
    );
    if return_plan {
        if let Some(plan) = &r.plan {
            out.push_str(",\"plan\":");
            write_mat(&mut out, plan);
        }
    }
    if let Some(sc) = &r.screen {
        out.push_str(",\"screen\":{\"scores\":[");
        for (i, s) in sc.scores.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            json::write_f64(&mut out, *s);
        }
        let _ = write!(out, "],\"slices\":{},\"hits\":[", sc.slices);
        for (i, h) in sc.hits.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            let _ = write!(out, "{{\"candidate\":{},\"sliced_score\":", h.candidate);
            json::write_f64(&mut out, h.sliced_score);
            out.push_str(",\"objective\":");
            json::write_f64(&mut out, h.objective);
            out.push('}');
        }
        out.push_str("]}");
    }
    out.push('}');
    out
}

fn write_mat(out: &mut String, m: &Mat) {
    out.push('[');
    for i in 0..m.rows() {
        if i > 0 {
            out.push(',');
        }
        out.push('[');
        for j in 0..m.cols() {
            if j > 0 {
                out.push(',');
            }
            json::write_f64(out, m[(i, j)]);
        }
        out.push(']');
    }
    out.push(']');
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{BackendChoice, ScreenHit, ScreenOutcome};
    use std::time::Duration;

    #[test]
    fn parses_a_gw1d_submit() {
        let body = br#"{
            "job": {"type": "gw1d", "u": [0.5, 0.5], "v": [0.25, 0.75], "k": 2, "epsilon": 0.01},
            "timeout_ms": 5000, "wait": true, "precision": "f32",
            "coupling_rank": "full", "max_retries": 1, "return_plan": true
        }"#;
        let sr = parse_submit(body).unwrap();
        match &sr.payload {
            JobPayload::Gw1d { u, v, k, epsilon } => {
                assert_eq!(u, &[0.5, 0.5]);
                assert_eq!(v, &[0.25, 0.75]);
                assert_eq!(*k, 2);
                assert_eq!(*epsilon, 0.01);
            }
            other => panic!("wrong payload {other:?}"),
        }
        assert!(sr.wait);
        assert!(sr.return_plan);
        let opts = sr.options();
        assert_eq!(opts.deadline, Some(Duration::from_millis(5000)));
        assert_eq!(opts.max_retries, 1);
        assert_eq!(opts.precision, Some(Precision::F32Refine));
        assert_eq!(opts.coupling, Some(CouplingRank::Full));
    }

    #[test]
    fn defaults_match_in_process_defaults() {
        let body = br#"{"job": {"type": "gw1d", "u": [0.5, 0.5], "v": [0.5, 0.5], "epsilon": 0.01}}"#;
        let sr = parse_submit(body).unwrap();
        assert!(!sr.wait);
        assert!(!sr.return_plan);
        assert_eq!(sr.options(), JobOptions::default());
        match sr.payload {
            JobPayload::Gw1d { k, .. } => assert_eq!(k, 1, "exponent defaults to 1"),
            other => panic!("wrong payload {other:?}"),
        }
    }

    #[test]
    fn parses_dense_mixed_and_screen_payloads() {
        let dense = br#"{"job": {"type": "gw_dense",
            "dx": [[0,1],[1,0]], "dy": [[0,2],[2,0]],
            "u": [0.5,0.5], "v": [0.5,0.5], "epsilon": 0.05}}"#;
        let sr = parse_submit(dense).unwrap();
        assert!(sr.payload.validate().is_ok(), "{:?}", sr.payload.validate());
        assert_eq!(sr.payload.family(), "dense");

        let mixed = br#"{"job": {"type": "gw_mixed",
            "dx": [[0,1],[1,0]], "grid": {"dim": 2, "n": 2, "h": 1.0},
            "u": [0.5,0.5], "v": [0.25,0.25,0.25,0.25], "epsilon": 0.05}}"#;
        let sr = parse_submit(mixed).unwrap();
        assert!(sr.payload.validate().is_ok(), "{:?}", sr.payload.validate());
        assert_eq!(sr.payload.family(), "mixed");

        let screen = br#"{"job": {"type": "gw_screen",
            "query": [[0,0],[1,1]], "candidates": [[[0,0],[2,2]], [[0,1],[1,0]]],
            "top_k": 1, "slices": 4, "epsilon": 0.05}}"#;
        let sr = parse_submit(screen).unwrap();
        assert!(sr.payload.validate().is_ok(), "{:?}", sr.payload.validate());
        match &sr.payload {
            JobPayload::GwScreen {
                candidates, top_k, slices, warm_start, ..
            } => {
                assert_eq!(candidates.len(), 2);
                assert_eq!(*top_k, 1);
                assert_eq!(*slices, 4);
                assert!(!*warm_start);
            }
            other => panic!("wrong payload {other:?}"),
        }
    }

    #[test]
    fn degenerate_grid_descriptor_parses_then_fails_validation() {
        // n = 1 would assert inside Grid2d::new; the wire layer must
        // instead surface a clean validation error.
        let body = br#"{"job": {"type": "gw_mixed",
            "dx": [[0]], "grid": {"dim": 2, "n": 1, "h": 1.0},
            "u": [1.0], "v": [1.0], "epsilon": 0.05}}"#;
        let sr = parse_submit(body).unwrap();
        assert!(sr.payload.validate().is_err());
    }

    #[test]
    fn submit_errors_are_descriptive() {
        for (body, needle) in [
            (&b"not json"[..], "unexpected"),
            (br#"{"jobs": {}}"#, "missing `job`"),
            (br#"{"job": {"type": "warp", "epsilon": 1}}"#, "unknown job type"),
            (
                br#"{"job": {"type": "gw1d", "u": [0.5, "x"], "v": [1.0], "epsilon": 1}}"#,
                "only numbers",
            ),
            (
                br#"{"job": {"type": "gw_dense", "dx": [[0,1],[1]], "dy": [[0]], "u": [1.0], "v": [1.0], "epsilon": 1}}"#,
                "inconsistent",
            ),
            (
                br#"{"job": {"type": "gw1d", "u": [0.5,0.5], "v": [0.5,0.5], "epsilon": 0.01}, "timeout_ms": -5}"#,
                "timeout_ms",
            ),
            (
                br#"{"job": {"type": "gw1d", "u": [0.5,0.5], "v": [0.5,0.5], "epsilon": 0.01}, "coupling_rank": 0}"#,
                "coupling_rank",
            ),
        ] {
            let err = parse_submit(body).unwrap_err();
            assert!(err.contains(needle), "{err:?} should mention {needle:?}");
        }
    }

    #[test]
    fn result_encoding_round_trips_floats_exactly() {
        let objective = std::f64::consts::PI / 7.0;
        let plan = Mat::from_fn(2, 3, |i, j| 1.0 / (1.0 + i as f64 + 3.0 * j as f64));
        let r = JobResult {
            id: 42,
            objective: Ok(objective),
            plan: Some(plan.clone()),
            backend: BackendChoice::NativeFgc,
            family: "grid1d",
            queue_time: Duration::from_micros(17),
            solve_time: Duration::from_micros(3000),
            screen: Some(ScreenOutcome {
                scores: vec![0.125, 1.0 / 3.0],
                hits: vec![ScreenHit {
                    candidate: 1,
                    sliced_score: 1.0 / 3.0,
                    objective: 0.7,
                }],
                slices: 8,
            }),
        };
        let body = encode_result(&r, true);
        let v = Json::parse(&body).unwrap();
        assert_eq!(v.get("id").and_then(Json::as_u64), Some(42));
        assert_eq!(v.get("ok").and_then(Json::as_bool), Some(true));
        assert_eq!(v.get("status").and_then(Json::as_str), Some("done"));
        assert_eq!(v.get("backend").and_then(Json::as_str), Some("native-fgc"));
        assert_eq!(v.get("family").and_then(Json::as_str), Some("grid1d"));
        assert_eq!(v.get("queue_us").and_then(Json::as_u64), Some(17));
        let got = v.get("objective").and_then(Json::as_f64).unwrap();
        assert_eq!(got.to_bits(), objective.to_bits());
        let rows = v.get("plan").and_then(Json::as_arr).unwrap();
        assert_eq!(rows.len(), 2);
        for (i, row) in rows.iter().enumerate() {
            let row = row.as_arr().unwrap();
            assert_eq!(row.len(), 3);
            for (j, x) in row.iter().enumerate() {
                assert_eq!(x.as_f64().unwrap().to_bits(), plan[(i, j)].to_bits());
            }
        }
        let screen = v.get("screen").unwrap();
        let scores = screen.get("scores").and_then(Json::as_arr).unwrap();
        assert_eq!(scores[1].as_f64().unwrap().to_bits(), (1.0f64 / 3.0).to_bits());
        assert_eq!(screen.get("slices").and_then(Json::as_u64), Some(8));
        let hits = screen.get("hits").and_then(Json::as_arr).unwrap();
        assert_eq!(hits[0].get("candidate").and_then(Json::as_u64), Some(1));

        // Plan elided unless asked for.
        let no_plan = encode_result(&r, false);
        assert!(Json::parse(&no_plan).unwrap().get("plan").is_none());
    }

    #[test]
    fn failed_results_carry_the_error() {
        let r = JobResult {
            id: 7,
            objective: Err("sinkhorn diverged".to_string()),
            plan: None,
            backend: BackendChoice::NativeNaive,
            family: "dense",
            queue_time: Duration::ZERO,
            solve_time: Duration::ZERO,
            screen: None,
        };
        let v = Json::parse(&encode_result(&r, false)).unwrap();
        assert_eq!(v.get("ok").and_then(Json::as_bool), Some(false));
        assert_eq!(
            v.get("error").and_then(Json::as_str),
            Some("sinkhorn diverged")
        );
        assert!(v.get("objective").is_none());
    }
}
