//! Prometheus text exposition (format 0.0.4) of a
//! [`MetricsSnapshot`].
//!
//! Scrape cost model: the whole body is counters/gauges plus
//! `LATENCY_FAMILIES.len() × LATENCY_BUCKETS` fixed histogram series —
//! every size in the render is a compile-time constant, so the scrape
//! path allocates `O(1)` in traffic served (the histogram rework in
//! `coordinator::metrics` exists exactly so this holds; the old `Vec`
//! reservoir would have made each scrape clone + sort every latency
//! ever recorded).
//!
//! The exposition format (names, labels, types) is pinned by a
//! golden-file test — change it deliberately or not at all.

use crate::coordinator::{bucket_upper_us, MetricsSnapshot, LATENCY_BUCKETS, LATENCY_FAMILIES};
use std::fmt::Write as _;

/// Render one snapshot as a Prometheus text-format body.
pub fn render_metrics(s: &MetricsSnapshot) -> String {
    let mut out = String::with_capacity(16 * 1024);

    counter(&mut out, "fgcgw_jobs_submitted_total", "Jobs admitted by the coordinator.", s.submitted);
    counter(&mut out, "fgcgw_jobs_rejected_total", "Jobs rejected at admission (validation, backpressure, shutdown).", s.rejected);
    counter(&mut out, "fgcgw_jobs_completed_total", "Jobs completed successfully.", s.completed);
    counter(&mut out, "fgcgw_jobs_failed_total", "Jobs that errored during solve.", s.failed);

    header(&mut out, "fgcgw_backend_jobs_total", "Completions per executing backend.", "counter");
    series(&mut out, "fgcgw_backend_jobs_total", "backend", "native-fgc", s.native_fgc);
    series(&mut out, "fgcgw_backend_jobs_total", "backend", "native-naive", s.native_naive);
    series(&mut out, "fgcgw_backend_jobs_total", "backend", "native-lowrank", s.native_lowrank);
    series(&mut out, "fgcgw_backend_jobs_total", "backend", "pjrt", s.pjrt);

    counter(&mut out, "fgcgw_warm_hits_total", "Jobs served by an already-warm worker workspace.", s.warm_hits);
    counter(&mut out, "fgcgw_warm_misses_total", "Jobs that forced a workspace build.", s.warm_misses);
    counter(&mut out, "fgcgw_steals_total", "Work-steal events across the worker pool.", s.steals);
    counter(&mut out, "fgcgw_sheds_total", "Depth-aware pin sheds (a subset of steals).", s.sheds);
    counter(&mut out, "fgcgw_worker_panics_total", "Worker panics caught by the isolation layer.", s.panics);
    counter(&mut out, "fgcgw_worker_respawns_total", "Worker solver-state respawns after caught panics.", s.respawns);

    header(&mut out, "fgcgw_retries_total", "Degradation-ladder retries per rung.", "counter");
    series(&mut out, "fgcgw_retries_total", "rung", "regime", s.retries_regime);
    series(&mut out, "fgcgw_retries_total", "rung", "anneal", s.retries_anneal);
    series(&mut out, "fgcgw_retries_total", "rung", "backend", s.retries_backend);

    counter(&mut out, "fgcgw_deadline_sheds_total", "Jobs shed because their deadline passed or could not be met.", s.deadline_sheds);
    counter(&mut out, "fgcgw_quarantines_total", "Jobs quarantined after repeatedly panicking the worker.", s.quarantines);
    counter(&mut out, "fgcgw_batch_splits_total", "Fused batches split for blast-radius containment.", s.batch_splits);
    counter(&mut out, "fgcgw_lost_results_total", "Results dropped because the receiver went away.", s.lost_results);
    counter(&mut out, "fgcgw_f32_served_total", "Jobs served on the f32 presolve + f64 refinement tier.", s.f32_served);
    counter(&mut out, "fgcgw_screened_candidates_total", "Candidates scored by the sliced screening tier.", s.screened);
    counter(&mut out, "fgcgw_escalated_candidates_total", "Screened candidates escalated to exact entropic solves.", s.escalated);

    gauge(&mut out, "fgcgw_warm_cache_units", "Live warm-cache occupancy in capacity units (f64-tier workspace = 2, f32-tier = 1).", s.warm_units);

    header(&mut out, "fgcgw_shard_depth", "Queue depth per shard at scrape time.", "gauge");
    for (i, depth) in s.shard_depths.iter().enumerate() {
        let _ = writeln!(out, "fgcgw_shard_depth{{shard=\"{i}\"}} {depth}");
    }

    header(&mut out, "fgcgw_mean_queue_seconds", "Mean queue wait over finished (completed + failed) jobs.", "gauge");
    let _ = writeln!(out, "fgcgw_mean_queue_seconds {}", s.mean_queue.as_secs_f64());
    header(&mut out, "fgcgw_mean_solve_seconds", "Mean solve time over finished (completed + failed) jobs.", "gauge");
    let _ = writeln!(out, "fgcgw_mean_solve_seconds {}", s.mean_solve.as_secs_f64());

    header(&mut out, "fgcgw_job_latency_seconds", "End-to-end job latency (queue + solve) per variant family.", "histogram");
    for (fi, family) in LATENCY_FAMILIES.iter().enumerate() {
        let h = &s.family_latency[fi];
        let mut cum = 0u64;
        for (i, &b) in h.buckets.iter().enumerate() {
            cum += b;
            if i + 1 == LATENCY_BUCKETS {
                let _ = writeln!(
                    out,
                    "fgcgw_job_latency_seconds_bucket{{family=\"{family}\",le=\"+Inf\"}} {cum}"
                );
            } else {
                let _ = writeln!(
                    out,
                    "fgcgw_job_latency_seconds_bucket{{family=\"{family}\",le=\"{}\"}} {cum}",
                    bucket_upper_us(i) as f64 / 1e6
                );
            }
        }
        let _ = writeln!(
            out,
            "fgcgw_job_latency_seconds_sum{{family=\"{family}\"}} {}",
            h.sum_us as f64 / 1e6
        );
        let _ = writeln!(
            out,
            "fgcgw_job_latency_seconds_count{{family=\"{family}\"}} {}",
            h.count
        );
    }
    out
}

fn header(out: &mut String, name: &str, help: &str, kind: &str) {
    let _ = writeln!(out, "# HELP {name} {help}");
    let _ = writeln!(out, "# TYPE {name} {kind}");
}

fn counter(out: &mut String, name: &str, help: &str, value: u64) {
    header(out, name, help, "counter");
    let _ = writeln!(out, "{name} {value}");
}

fn gauge(out: &mut String, name: &str, help: &str, value: u64) {
    header(out, name, help, "gauge");
    let _ = writeln!(out, "{name} {value}");
}

fn series(out: &mut String, name: &str, label: &str, label_value: &str, value: u64) {
    let _ = writeln!(out, "{name}{{{label}=\"{label_value}\"}} {value}");
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{BackendChoice, ServiceMetrics};
    use std::time::Duration;

    #[test]
    fn exposition_counts_match_the_snapshot() {
        let m = ServiceMetrics::new();
        for _ in 0..5 {
            m.on_submit();
        }
        m.on_reject();
        m.on_complete(
            &BackendChoice::NativeFgc,
            "grid1d",
            true,
            Duration::from_micros(3),
            Duration::from_micros(100),
        );
        m.on_complete(
            &BackendChoice::NativeLowRank,
            "dense",
            false,
            Duration::from_micros(10),
            Duration::from_micros(900),
        );
        let mut s = m.snapshot();
        s.shard_depths = vec![2, 0, 1];
        let text = render_metrics(&s);
        for needle in [
            "fgcgw_jobs_submitted_total 5",
            "fgcgw_jobs_rejected_total 1",
            "fgcgw_jobs_completed_total 1",
            "fgcgw_jobs_failed_total 1",
            "fgcgw_backend_jobs_total{backend=\"native-fgc\"} 1",
            "fgcgw_backend_jobs_total{backend=\"native-lowrank\"} 1",
            "fgcgw_shard_depth{shard=\"0\"} 2",
            "fgcgw_shard_depth{shard=\"2\"} 1",
            "fgcgw_job_latency_seconds_count{family=\"grid1d\"} 1",
            "fgcgw_job_latency_seconds_count{family=\"dense\"} 1",
            "fgcgw_job_latency_seconds_count{family=\"screen\"} 0",
            "fgcgw_job_latency_seconds_bucket{family=\"grid1d\",le=\"+Inf\"} 1",
            "# TYPE fgcgw_job_latency_seconds histogram",
            "# TYPE fgcgw_warm_cache_units gauge",
        ] {
            assert!(text.contains(needle), "missing {needle:?} in:\n{text}");
        }
        // 103µs lands in the (63µs, 127µs] bucket: cumulative counts
        // must flip from 0 to 1 across that boundary.
        assert!(text.contains("fgcgw_job_latency_seconds_bucket{family=\"grid1d\",le=\"0.000063\"} 0"));
        assert!(text.contains("fgcgw_job_latency_seconds_bucket{family=\"grid1d\",le=\"0.000127\"} 1"));
    }

    #[test]
    fn histogram_buckets_are_cumulative_and_end_at_count() {
        let m = ServiceMetrics::new();
        for us in [1u64, 10, 100, 1000, 10_000] {
            m.on_complete(
                &BackendChoice::NativeFgc,
                "screen",
                true,
                Duration::ZERO,
                Duration::from_micros(us),
            );
        }
        let text = render_metrics(&m.snapshot());
        let mut last = 0u64;
        let mut bucket_lines = 0;
        for line in text.lines() {
            if let Some(rest) = line.strip_prefix("fgcgw_job_latency_seconds_bucket{family=\"screen\",") {
                let value: u64 = rest.rsplit(' ').next().unwrap().parse().unwrap();
                assert!(value >= last, "buckets must be cumulative: {line}");
                last = value;
                bucket_lines += 1;
            }
        }
        assert_eq!(bucket_lines, crate::coordinator::LATENCY_BUCKETS);
        assert_eq!(last, 5, "+Inf bucket must equal the count");
        assert!(text.contains("fgcgw_job_latency_seconds_count{family=\"screen\"} 5"));
    }

    #[test]
    fn scrape_size_is_traffic_independent() {
        let quiet = ServiceMetrics::new();
        let busy = ServiceMetrics::new();
        for i in 0..10_000u64 {
            busy.on_submit();
            busy.on_complete(
                &BackendChoice::NativeFgc,
                "grid1d",
                true,
                Duration::from_micros(i % 97),
                Duration::from_micros(i % 1013),
            );
        }
        let a = render_metrics(&quiet.snapshot()).len();
        let b = render_metrics(&busy.snapshot()).len();
        // Only digit widths may differ — the series set is fixed.
        assert!(
            (a as i64 - b as i64).unsigned_abs() < 512,
            "scrape body size should not scale with traffic ({a} vs {b})"
        );
    }
}
