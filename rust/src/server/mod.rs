//! Wire-level serving front-end: a std-only TCP/HTTP ingest layer
//! over the [`Coordinator`].
//!
//! Every serving tier built below the coordinator (sharded warm
//! batching, the fault ladder, the f32/low-rank/sliced tiers) was
//! reachable only in-process until this module; the server puts a
//! socket in front of `submit_with_options` without adding a single
//! dependency — hand-rolled HTTP/1.1 ([`http`]), hand-rolled JSON
//! ([`json`]), Prometheus text exposition ([`prometheus`]).
//!
//! Endpoints:
//! * `POST /jobs` — submit a job ([`wire`] documents the body). With
//!   `"wait": true` the connection holds until the result; otherwise
//!   `202` returns the id for polling. The wire `timeout_ms` maps
//!   onto [`crate::coordinator::JobOptions::deadline`], so a wire
//!   timeout the service cannot meet surfaces as the coordinator's
//!   own deadline-shed rejection (`429`).
//! * `GET /jobs/<id>` — poll an async submission (`202` pending,
//!   `200` done; terminal bodies are cached for re-polls). A waiting
//!   submission that timed out on the wire (`504`) stays pollable.
//! * `GET /healthz` — liveness.
//! * `GET /metrics` — Prometheus text exposition of the coordinator
//!   metrics; `O(1)` allocation in traffic served.
//! * `POST /shutdown` — request a graceful stop; the serve loop
//!   observes it via [`Server::shutdown_requested`].
//!
//! Threading model: a nonblocking accept loop (named `fgcgw-accept`)
//! polls a stop flag between accepts and spawns one `fgcgw-http`
//! thread per connection (one request per connection,
//! `connection: close`), capped at
//! [`ServerConfig::max_connections`] live handlers — beyond that new
//! connections get an immediate `503` instead of an unbounded thread
//! pile-up. Graceful [`Server::shutdown`] joins the accept loop and
//! every live handler, then hands the still-undelivered result
//! receivers back to the caller so the coordinator's own drain can
//! deliver into live channels — the loopback tests assert
//! `lost_results` stays 0 across a shutdown with jobs in flight.

pub mod http;
pub mod json;
pub mod prometheus;
pub mod wire;

pub use http::{read_request, write_response, HttpError, Request};
pub use json::Json;
pub use prometheus::render_metrics;
pub use wire::{encode_result, parse_submit, SubmitRequest};

use crate::coordinator::{Coordinator, JobId, JobResult};
use crate::error::{Error, Result};
use std::collections::{HashMap, VecDeque};
use std::io::ErrorKind;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

/// Poll cadence of the nonblocking accept loop while idle.
const ACCEPT_POLL: Duration = Duration::from_millis(5);
/// Terminal result bodies kept for re-polls before eviction (oldest
/// first) — bounds registry memory under sustained async traffic.
const DONE_CACHE_MAX: usize = 1024;
/// Un-polled async submissions admitted before `429` — each holds a
/// live result receiver, so this bounds them.
const PENDING_MAX: usize = 4096;
/// Grace added to a waiting submit's deadline before the wire gives
/// up (`504`): the job's own deadline shed should win the race, so
/// the client sees the coordinator's terminal result, not the wire's.
const WAIT_GRACE: Duration = Duration::from_secs(1);
/// Wait cap for `"wait": true` submissions without a deadline.
const WAIT_MAX: Duration = Duration::from_secs(3600);

const TEXT: &str = "text/plain; charset=utf-8";
const JSON_TYPE: &str = "application/json";
/// Prometheus text exposition format version 0.0.4.
const PROM: &str = "text/plain; version=0.0.4";

/// Server tuning knobs.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Bind address, e.g. `127.0.0.1:8077` (port `0` picks a free
    /// one — read it back from [`Server::local_addr`]).
    pub listen: String,
    /// Live connection handlers before new connections get `503`.
    pub max_connections: usize,
    /// Request body cap in bytes (`413` beyond it).
    pub max_body_bytes: usize,
    /// Per-connection socket read timeout.
    pub read_timeout: Duration,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            listen: "127.0.0.1:0".to_string(),
            max_connections: 64,
            max_body_bytes: 8 * 1024 * 1024,
            read_timeout: Duration::from_secs(10),
        }
    }
}

/// One async job's wire-side state.
enum WireJob {
    /// Submitted, result not yet retrieved.
    Pending {
        rx: mpsc::Receiver<JobResult>,
        return_plan: bool,
    },
    /// Terminal response body, cached for re-polls.
    Done { status: u16, body: String },
}

/// Async-job registry: id → state, plus the eviction queue for
/// terminal bodies and the live pending count.
#[derive(Default)]
struct Registry {
    jobs: HashMap<JobId, WireJob>,
    done_order: VecDeque<JobId>,
    pending: usize,
}

impl Registry {
    /// Transition an entry to its terminal body (the entry itself was
    /// already taken out of `jobs` by the caller), evicting the
    /// oldest cached bodies beyond [`DONE_CACHE_MAX`].
    fn finish(&mut self, id: JobId, status: u16, body: String) {
        self.pending = self.pending.saturating_sub(1);
        self.jobs.insert(id, WireJob::Done { status, body });
        self.done_order.push_back(id);
        while self.done_order.len() > DONE_CACHE_MAX {
            if let Some(old) = self.done_order.pop_front() {
                self.jobs.remove(&old);
            }
        }
    }
}

/// State shared between the accept loop and connection handlers.
struct ServeCtx {
    coord: Arc<Coordinator>,
    cfg: ServerConfig,
    registry: Mutex<Registry>,
    stop: Arc<AtomicBool>,
    shutdown_requested: AtomicBool,
}

/// A running wire front-end. Dropping it without
/// [`Server::shutdown`] detaches the threads; shut down explicitly.
pub struct Server {
    addr: SocketAddr,
    ctx: Arc<ServeCtx>,
    stop: Arc<AtomicBool>,
    accept: JoinHandle<Vec<JoinHandle<()>>>,
}

impl Server {
    /// Bind `cfg.listen` and start serving `coord` over it.
    pub fn start(coord: Arc<Coordinator>, cfg: ServerConfig) -> Result<Server> {
        let listener = TcpListener::bind(&cfg.listen)
            .map_err(|e| Error::Io(format!("bind {}", cfg.listen), e))?;
        let addr = listener
            .local_addr()
            .map_err(|e| Error::Io("listener local_addr".to_string(), e))?;
        listener
            .set_nonblocking(true)
            .map_err(|e| Error::Io("listener set_nonblocking".to_string(), e))?;
        let stop = Arc::new(AtomicBool::new(false));
        let ctx = Arc::new(ServeCtx {
            coord,
            cfg,
            registry: Mutex::new(Registry::default()),
            stop: Arc::clone(&stop),
            shutdown_requested: AtomicBool::new(false),
        });
        let loop_ctx = Arc::clone(&ctx);
        let accept = std::thread::Builder::new()
            .name("fgcgw-accept".to_string())
            .spawn(move || accept_loop(listener, loop_ctx))
            .map_err(|e| Error::Io("spawn accept loop".to_string(), e))?;
        Ok(Server {
            addr,
            ctx,
            stop,
            accept,
        })
    }

    /// The bound address (resolves `:0` to the picked port).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// True once a client has `POST`ed `/shutdown`. The owner of the
    /// serve loop decides when to act on it (and then calls
    /// [`Server::shutdown`]).
    pub fn shutdown_requested(&self) -> bool {
        self.ctx.shutdown_requested.load(Ordering::SeqCst)
    }

    /// Graceful stop: cease accepting, join every in-flight handler
    /// (each drains to a written response — a held `"wait": true`
    /// submit finishes, it is not cut off), and return the result
    /// receivers of async jobs never polled to completion.
    ///
    /// The caller must keep those receivers alive across
    /// `Coordinator::shutdown` so the coordinator's drain delivers
    /// into live channels — dropping them first would count every
    /// undelivered result in `lost_results` — and then drain them.
    #[must_use = "keep the pending receivers alive across Coordinator::shutdown, then drain them"]
    pub fn shutdown(self) -> Vec<(JobId, mpsc::Receiver<JobResult>)> {
        self.stop.store(true, Ordering::SeqCst);
        let handlers = self.accept.join().unwrap_or_default();
        for h in handlers {
            let _ = h.join();
        }
        let mut reg = self.ctx.registry.lock().unwrap();
        let jobs = std::mem::take(&mut reg.jobs);
        reg.done_order.clear();
        reg.pending = 0;
        drop(reg);
        jobs.into_iter()
            .filter_map(|(id, job)| match job {
                WireJob::Pending { rx, .. } => Some((id, rx)),
                WireJob::Done { .. } => None,
            })
            .collect()
    }
}

fn accept_loop(listener: TcpListener, ctx: Arc<ServeCtx>) -> Vec<JoinHandle<()>> {
    let mut handlers: Vec<JoinHandle<()>> = Vec::new();
    while !ctx.stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((mut stream, _peer)) => {
                handlers.retain(|h| !h.is_finished());
                if handlers.len() >= ctx.cfg.max_connections {
                    let _ = http::write_response(
                        &mut stream,
                        503,
                        TEXT,
                        b"connection capacity reached\n",
                    );
                    continue;
                }
                let conn_ctx = Arc::clone(&ctx);
                let spawned = std::thread::Builder::new()
                    .name("fgcgw-http".to_string())
                    .spawn(move || handle_connection(stream, &conn_ctx));
                match spawned {
                    Ok(h) => handlers.push(h),
                    Err(e) => eprintln!("[fgcgw] http handler spawn failed: {e}"),
                }
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => std::thread::sleep(ACCEPT_POLL),
            Err(e) => {
                eprintln!("[fgcgw] accept error: {e}");
                std::thread::sleep(ACCEPT_POLL);
            }
        }
    }
    handlers
}

fn handle_connection(mut stream: TcpStream, ctx: &ServeCtx) {
    let _ = stream.set_read_timeout(Some(ctx.cfg.read_timeout));
    let _ = stream.set_nodelay(true);
    let req = match http::read_request(&mut stream, ctx.cfg.max_body_bytes) {
        Ok(req) => req,
        Err(HttpError::TooLarge) => {
            let _ = http::write_response(&mut stream, 413, TEXT, b"request body too large\n");
            return;
        }
        Err(HttpError::BadRequest(msg)) => {
            let _ = http::write_response(&mut stream, 400, TEXT, format!("{msg}\n").as_bytes());
            return;
        }
        // Transport failure (including a read timeout): nothing
        // useful to write back.
        Err(HttpError::Io(_)) => return,
    };
    let (status, content_type, body) = route(&req, ctx);
    let _ = http::write_response(&mut stream, status, content_type, body.as_bytes());
}

fn route(req: &Request, ctx: &ServeCtx) -> (u16, &'static str, String) {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => (200, TEXT, "ok\n".to_string()),
        ("GET", "/metrics") => (200, PROM, prometheus::render_metrics(&ctx.coord.metrics())),
        ("POST", "/jobs") => handle_submit(req, ctx),
        ("POST", "/shutdown") => {
            ctx.shutdown_requested.store(true, Ordering::SeqCst);
            (200, JSON_TYPE, "{\"status\":\"shutting-down\"}".to_string())
        }
        ("GET", path) if path.starts_with("/jobs/") => handle_poll(path, ctx),
        _ => (404, TEXT, "not found\n".to_string()),
    }
}

fn handle_submit(req: &Request, ctx: &ServeCtx) -> (u16, &'static str, String) {
    let sr = match wire::parse_submit(&req.body) {
        Ok(sr) => sr,
        Err(msg) => return (400, JSON_TYPE, wire::encode_error(&msg)),
    };
    // Pre-validate so malformed payloads come back `400`; the
    // coordinator re-validates at admission, but its rejection is the
    // generic `429` the wire reserves for backpressure-style sheds.
    if let Err(msg) = sr.payload.validate() {
        return (400, JSON_TYPE, wire::encode_error(&format!("validation: {msg}")));
    }
    let options = sr.options();
    if sr.wait {
        match ctx.coord.submit_with_options(sr.payload, options) {
            Ok((id, rx)) => {
                let wait = options
                    .deadline
                    .map_or(WAIT_MAX, |d| d.saturating_add(WAIT_GRACE));
                match rx.recv_timeout(wait) {
                    Ok(result) => (200, JSON_TYPE, wire::encode_result(&result, sr.return_plan)),
                    Err(mpsc::RecvTimeoutError::Timeout) => {
                        // Park the receiver: the eventual (likely
                        // deadline-shed) result drains at shutdown
                        // instead of counting lost, and the job stays
                        // pollable at `GET /jobs/<id>`.
                        let mut reg = ctx.registry.lock().unwrap();
                        if reg.pending < PENDING_MAX {
                            reg.pending += 1;
                            reg.jobs.insert(
                                id,
                                WireJob::Pending {
                                    rx,
                                    return_plan: sr.return_plan,
                                },
                            );
                        }
                        drop(reg);
                        (
                            504,
                            JSON_TYPE,
                            wire::encode_error(&format!(
                                "no result within {wait:?}; job {id} remains pollable"
                            )),
                        )
                    }
                    Err(mpsc::RecvTimeoutError::Disconnected) => (
                        500,
                        JSON_TYPE,
                        wire::encode_error("worker dropped the result channel"),
                    ),
                }
            }
            Err(e) => submit_error(e),
        }
    } else {
        // Reserve the registry slot BEFORE submitting: admitting a
        // job whose receiver then cannot be registered would strand
        // its result (the worker's send would count a lost result).
        {
            let mut reg = ctx.registry.lock().unwrap();
            if reg.pending >= PENDING_MAX {
                return (
                    429,
                    JSON_TYPE,
                    wire::encode_error("too many unpolled jobs; poll results or retry later"),
                );
            }
            reg.pending += 1;
        }
        match ctx.coord.submit_with_options(sr.payload, options) {
            Ok((id, rx)) => {
                let mut reg = ctx.registry.lock().unwrap();
                reg.jobs.insert(
                    id,
                    WireJob::Pending {
                        rx,
                        return_plan: sr.return_plan,
                    },
                );
                drop(reg);
                (202, JSON_TYPE, wire::encode_queued(id))
            }
            Err(e) => {
                ctx.registry.lock().unwrap().pending -= 1;
                submit_error(e)
            }
        }
    }
}

fn submit_error(e: Error) -> (u16, &'static str, String) {
    match e {
        // Admission rejections (validation, backpressure, deadline
        // shed, shutdown) are the client's `429` to back off on.
        Error::Rejected(msg) => (429, JSON_TYPE, wire::encode_error(&msg)),
        other => (500, JSON_TYPE, wire::encode_error(&other.to_string())),
    }
}

fn handle_poll(path: &str, ctx: &ServeCtx) -> (u16, &'static str, String) {
    let id: JobId = match path.strip_prefix("/jobs/").and_then(|s| s.parse().ok()) {
        Some(id) => id,
        None => return (400, JSON_TYPE, wire::encode_error("job id must be an integer")),
    };
    let mut reg = ctx.registry.lock().unwrap();
    let Some(job) = reg.jobs.remove(&id) else {
        return (
            404,
            JSON_TYPE,
            wire::encode_error("unknown job id (never submitted here, or evicted after retrieval)"),
        );
    };
    match job {
        WireJob::Done { status, body } => {
            let response = (status, JSON_TYPE, body.clone());
            reg.jobs.insert(id, WireJob::Done { status, body });
            response
        }
        WireJob::Pending { rx, return_plan } => match rx.try_recv() {
            Err(mpsc::TryRecvError::Empty) => {
                reg.jobs.insert(id, WireJob::Pending { rx, return_plan });
                (202, JSON_TYPE, wire::encode_pending(id))
            }
            Ok(result) => {
                let body = wire::encode_result(&result, return_plan);
                reg.finish(id, 200, body.clone());
                (200, JSON_TYPE, body)
            }
            Err(mpsc::TryRecvError::Disconnected) => {
                let body = wire::encode_error("worker dropped the result channel");
                reg.finish(id, 500, body.clone());
                (500, JSON_TYPE, body)
            }
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_finish_caps_the_done_cache() {
        let mut reg = Registry::default();
        for id in 0..(DONE_CACHE_MAX as JobId + 10) {
            reg.pending += 1;
            // Simulate the handler taking the pending entry out
            // before finishing it.
            reg.finish(id, 200, format!("{{\"id\":{id}}}"));
        }
        assert_eq!(reg.jobs.len(), DONE_CACHE_MAX);
        assert_eq!(reg.done_order.len(), DONE_CACHE_MAX);
        // Oldest evicted, newest kept.
        assert!(!reg.jobs.contains_key(&0));
        assert!(reg.jobs.contains_key(&(DONE_CACHE_MAX as JobId + 9)));
        assert_eq!(reg.pending, 0);
    }
}
