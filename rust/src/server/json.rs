//! Minimal JSON codec for the wire layer — std-only, no dependencies.
//!
//! The parser is a recursive-descent reader over the raw request body
//! with a hard nesting cap. Numbers are parsed by handing the exact
//! source token to `str::parse::<f64>`, and the writer prints finite
//! floats with Rust's shortest-round-trip `Display`, so a float that
//! crosses the wire in both directions restores to identical bits —
//! the loopback tests pin this bit-for-bit.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// Hard cap on array/object nesting (a hostile body like `[[[[...`
/// must not overflow the handler thread's stack).
const MAX_DEPTH: usize = 64;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number. Parsed via `str::parse::<f64>` on the exact
    /// source token; non-finite results are rejected at parse time.
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object. `BTreeMap` keeps key order deterministic.
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a complete JSON document; trailing non-whitespace is an
    /// error.
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value(0)?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(format!("trailing data at byte {}", p.pos));
        }
        Ok(v)
    }

    /// Object field lookup; `None` for missing keys or non-objects.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// The numeric value, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// The value as a non-negative integer, if it is a number with no
    /// fractional part.
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(x) if *x >= 0.0 && x.fract() == 0.0 && *x <= u64::MAX as f64 => {
                Some(*x as u64)
            }
            _ => None,
        }
    }

    /// [`Json::as_u64`] narrowed to `usize`.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().and_then(|v| usize::try_from(v).ok())
    }

    /// The boolean value, if this is a boolean.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// The string contents, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The array items, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(items) => Some(items),
            _ => None,
        }
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl Parser<'_> {
    fn skip_ws(&mut self) {
        while let Some(&b) = self.bytes.get(self.pos) {
            if matches!(b, b' ' | b'\t' | b'\r' | b'\n') {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!("expected `{}` at byte {}", b as char, self.pos))
        }
    }

    fn value(&mut self, depth: usize) -> Result<Json, String> {
        if depth > MAX_DEPTH {
            return Err("nesting too deep".to_string());
        }
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(depth),
            Some(b'{') => self.object(depth),
            Some(b) if b == b'-' || b.is_ascii_digit() => self.number(),
            Some(b) => Err(format!("unexpected byte `{}` at {}", b as char, self.pos)),
            None => Err("unexpected end of input".to_string()),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(format!("invalid literal at byte {}", self.pos))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        while let Some(&b) = self.bytes.get(self.pos) {
            if matches!(b, b'-' | b'+' | b'.' | b'e' | b'E') || b.is_ascii_digit() {
                self.pos += 1;
            } else {
                break;
            }
        }
        // The token alphabet above excludes "inf"/"NaN" spellings, and
        // overflowing literals like `1e999` parse to infinity — reject
        // those too so payload validation only ever sees finite input.
        let tok = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| "non-UTF-8 number token".to_string())?;
        match tok.parse::<f64>() {
            Ok(x) if x.is_finite() => Ok(Json::Num(x)),
            _ => Err(format!("invalid number `{tok}` at byte {start}")),
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let b = self
                .peek()
                .ok_or_else(|| "unterminated string".to_string())?;
            self.pos += 1;
            match b {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self
                        .peek()
                        .ok_or_else(|| "unterminated escape".to_string())?;
                    self.pos += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{0008}'),
                        b'f' => out.push('\u{000C}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => out.push(self.unicode_escape()?),
                        other => return Err(format!("bad escape `\\{}`", other as char)),
                    }
                }
                _ => {
                    // Copy the raw UTF-8 span up to the next quote or
                    // escape in one shot.
                    self.pos -= 1;
                    let start = self.pos;
                    while let Some(&c) = self.bytes.get(self.pos) {
                        if c == b'"' || c == b'\\' {
                            break;
                        }
                        if c < 0x20 {
                            return Err("control character in string".to_string());
                        }
                        self.pos += 1;
                    }
                    let chunk = std::str::from_utf8(&self.bytes[start..self.pos])
                        .map_err(|_| "invalid UTF-8 in string".to_string())?;
                    out.push_str(chunk);
                }
            }
        }
    }

    fn unicode_escape(&mut self) -> Result<char, String> {
        let hi = self.hex4()?;
        if (0xD800..0xDC00).contains(&hi) {
            // High surrogate: a `\uXXXX` low surrogate must follow.
            if self.bytes.get(self.pos) == Some(&b'\\') && self.bytes.get(self.pos + 1) == Some(&b'u')
            {
                self.pos += 2;
                let lo = self.hex4()?;
                if !(0xDC00..0xE000).contains(&lo) {
                    return Err("invalid low surrogate".to_string());
                }
                let code = 0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00);
                char::from_u32(code).ok_or_else(|| "invalid surrogate pair".to_string())
            } else {
                Err("lone high surrogate".to_string())
            }
        } else if (0xDC00..0xE000).contains(&hi) {
            Err("lone low surrogate".to_string())
        } else {
            char::from_u32(hi).ok_or_else(|| "invalid \\u escape".to_string())
        }
    }

    fn hex4(&mut self) -> Result<u32, String> {
        let end = self.pos.checked_add(4).filter(|&e| e <= self.bytes.len());
        let end = end.ok_or_else(|| "truncated \\u escape".to_string())?;
        let tok = std::str::from_utf8(&self.bytes[self.pos..end])
            .map_err(|_| "bad \\u escape".to_string())?;
        let v = u32::from_str_radix(tok, 16).map_err(|_| "bad \\u escape".to_string())?;
        self.pos = end;
        Ok(v)
    }

    fn array(&mut self, depth: usize) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value(depth + 1)?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(format!("expected `,` or `]` at byte {}", self.pos)),
            }
        }
    }

    fn object(&mut self, depth: usize) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let value = self.value(depth + 1)?;
            map.insert(key, value);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(format!("expected `,` or `}}` at byte {}", self.pos)),
            }
        }
    }
}

/// Append `s` to `out` as a JSON string literal, escaping as needed.
pub fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Append a JSON number: Rust's shortest-round-trip `Display` for
/// finite values (so `str::parse::<f64>` restores identical bits),
/// `null` for non-finite ones — JSON has no inf/NaN.
pub fn write_f64(out: &mut String, x: f64) {
    if x.is_finite() {
        let _ = write!(out, "{x}");
    } else {
        out.push_str("null");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars_and_containers() {
        let v = Json::parse(r#" {"a": [1, -2.5, true, null], "b": {"c": "hi"}} "#).unwrap();
        let a = v.get("a").and_then(Json::as_arr).unwrap();
        assert_eq!(a[0].as_f64(), Some(1.0));
        assert_eq!(a[1].as_f64(), Some(-2.5));
        assert_eq!(a[2].as_bool(), Some(true));
        assert_eq!(a[3], Json::Null);
        assert_eq!(
            v.get("b").and_then(|b| b.get("c")).and_then(Json::as_str),
            Some("hi")
        );
    }

    #[test]
    fn floats_round_trip_bit_for_bit() {
        for &x in &[0.1, 1.0 / 3.0, std::f64::consts::PI, 1e-300, -4.9e-324, 2.5] {
            let mut s = String::new();
            write_f64(&mut s, x);
            let back = Json::parse(&s).unwrap().as_f64().unwrap();
            assert_eq!(back.to_bits(), x.to_bits(), "{x} -> {s} -> {back}");
        }
    }

    #[test]
    fn non_finite_writes_null_and_non_finite_literals_rejected() {
        let mut s = String::new();
        write_f64(&mut s, f64::NAN);
        assert_eq!(s, "null");
        assert!(Json::parse("1e999").is_err());
        assert!(Json::parse("inf").is_err());
        assert!(Json::parse("NaN").is_err());
    }

    #[test]
    fn string_escapes_round_trip() {
        let original = "line1\nline2\t\"quoted\" \\ slash \u{1F600} ünïcode \u{0007}";
        let mut s = String::new();
        write_str(&mut s, original);
        assert_eq!(Json::parse(&s).unwrap().as_str(), Some(original));
        // Surrogate-pair escape form decodes too.
        assert_eq!(
            Json::parse(r#""\ud83d\ude00""#).unwrap().as_str(),
            Some("\u{1F600}")
        );
    }

    #[test]
    fn integer_accessors_reject_fractions_and_negatives() {
        assert_eq!(Json::parse("7").unwrap().as_u64(), Some(7));
        assert_eq!(Json::parse("7.5").unwrap().as_u64(), None);
        assert_eq!(Json::parse("-7").unwrap().as_u64(), None);
    }

    #[test]
    fn malformed_documents_error_without_panicking() {
        for bad in [
            "", "{", "[1,", "{\"a\"}", "tru", "\"unterminated", "1 2", "{\"a\":}",
            "\"\\q\"", "\"\\ud800\"", "\"\u{0001}\"",
        ] {
            assert!(Json::parse(bad).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn nesting_depth_is_capped() {
        let deep = "[".repeat(100) + &"]".repeat(100);
        assert!(Json::parse(&deep).is_err());
        let ok = "[".repeat(20) + &"]".repeat(20);
        assert!(Json::parse(&ok).is_ok());
    }
}
