//! Minimal HTTP/1.1 over std's blocking sockets — just enough for the
//! wire protocol: request line + headers + optional `content-length`
//! body in, a plain response out, one request per connection
//! (`connection: close`). No keep-alive, no chunked encoding, no TLS.
//!
//! The reader is generic over [`Read`] (and the writer over
//! [`Write`]) so the parsing is unit-testable without sockets.

use std::io::{Read, Write};

/// Hard cap on the header section (request line + headers). A client
/// that streams headers forever is cut off at this size.
const MAX_HEAD_BYTES: usize = 16 * 1024;

/// How much of an over-cap body the reader discards before giving up
/// on the connection. Closing with unread request bytes makes the
/// kernel reset the connection, which can destroy the `413` response
/// before the client reads it — so moderately oversized bodies are
/// drained and only unbounded ones get cut off.
const DRAIN_MAX_BYTES: usize = 256 * 1024;

/// One parsed request.
#[derive(Debug)]
pub struct Request {
    /// Request method (`GET`, `POST`, ...).
    pub method: String,
    /// Request target path. Query strings are not split off — the
    /// wire API does not use them.
    pub path: String,
    /// Request body (`content-length` bytes; empty when absent).
    pub body: Vec<u8>,
}

/// Why a request could not be read.
#[derive(Debug)]
pub enum HttpError {
    /// Transport failure (including socket read timeouts) — no
    /// response can usefully be written.
    Io(std::io::Error),
    /// The request was malformed; respond `400`.
    BadRequest(String),
    /// The declared body exceeds the configured cap; respond `413`.
    TooLarge,
}

impl From<std::io::Error> for HttpError {
    fn from(e: std::io::Error) -> Self {
        HttpError::Io(e)
    }
}

/// Read and parse one request. `max_body` caps the accepted
/// `content-length`; larger declarations fail with
/// [`HttpError::TooLarge`] after a best-effort bounded drain of the
/// declared body (see `DRAIN_MAX_BYTES`), so the `413` response
/// survives the close.
pub fn read_request<S: Read>(stream: &mut S, max_body: usize) -> Result<Request, HttpError> {
    let (head, mut leftover) = read_head(stream)?;
    let mut lines = head.split("\r\n");
    let request_line = lines
        .next()
        .ok_or_else(|| HttpError::BadRequest("empty request".to_string()))?;
    let mut parts = request_line.split(' ');
    let method = parts
        .next()
        .filter(|m| !m.is_empty())
        .ok_or_else(|| HttpError::BadRequest("missing method".to_string()))?
        .to_string();
    let path = parts
        .next()
        .filter(|p| p.starts_with('/'))
        .ok_or_else(|| HttpError::BadRequest("missing request path".to_string()))?
        .to_string();

    let mut content_length = 0usize;
    for line in lines {
        if let Some((name, value)) = line.split_once(':') {
            if name.eq_ignore_ascii_case("content-length") {
                content_length = value
                    .trim()
                    .parse()
                    .map_err(|_| HttpError::BadRequest("bad content-length".to_string()))?;
            }
        }
    }
    if content_length > max_body {
        let mut remaining = content_length
            .saturating_sub(leftover.len())
            .min(DRAIN_MAX_BYTES);
        let mut chunk = [0u8; 4096];
        while remaining > 0 {
            match stream.read(&mut chunk[..remaining.min(chunk.len())]) {
                Ok(0) | Err(_) => break,
                Ok(n) => remaining -= n,
            }
        }
        return Err(HttpError::TooLarge);
    }

    // `leftover` holds body bytes that arrived in the same reads as
    // the header section; pull the remainder off the stream.
    leftover.truncate(content_length.min(leftover.len()));
    let mut body = leftover;
    while body.len() < content_length {
        let mut chunk = [0u8; 4096];
        let want = (content_length - body.len()).min(chunk.len());
        let n = stream.read(&mut chunk[..want])?;
        if n == 0 {
            return Err(HttpError::BadRequest("body shorter than content-length".to_string()));
        }
        body.extend_from_slice(&chunk[..n]);
    }
    Ok(Request { method, path, body })
}

/// Read up to the `\r\n\r\n` header terminator. Returns the header
/// text and any extra bytes read past the terminator (the body
/// prefix).
fn read_head<S: Read>(stream: &mut S) -> Result<(String, Vec<u8>), HttpError> {
    let mut buf: Vec<u8> = Vec::with_capacity(1024);
    loop {
        if let Some(end) = find_terminator(&buf) {
            let leftover = buf.split_off(end + 4);
            buf.truncate(end);
            let head = String::from_utf8(buf)
                .map_err(|_| HttpError::BadRequest("non-UTF-8 header".to_string()))?;
            return Ok((head, leftover));
        }
        if buf.len() >= MAX_HEAD_BYTES {
            return Err(HttpError::BadRequest("header section too large".to_string()));
        }
        let mut chunk = [0u8; 1024];
        let n = stream.read(&mut chunk)?;
        if n == 0 {
            return Err(HttpError::BadRequest("connection closed mid-header".to_string()));
        }
        buf.extend_from_slice(&chunk[..n]);
    }
}

fn find_terminator(buf: &[u8]) -> Option<usize> {
    buf.windows(4).position(|w| w == b"\r\n\r\n")
}

/// Write a complete response and flush. Always closes the exchange
/// (`connection: close`) — the accept loop hands out one request per
/// connection.
pub fn write_response<S: Write>(
    stream: &mut S,
    status: u16,
    content_type: &str,
    body: &[u8],
) -> std::io::Result<()> {
    let head = format!(
        "HTTP/1.1 {status} {}\r\ncontent-type: {content_type}\r\ncontent-length: {}\r\nconnection: close\r\n\r\n",
        reason_phrase(status),
        body.len(),
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(body)?;
    stream.flush()
}

fn reason_phrase(status: u16) -> &'static str {
    match status {
        200 => "OK",
        202 => "Accepted",
        400 => "Bad Request",
        404 => "Not Found",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        _ => "Unknown",
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn parses_request_with_body() {
        let raw = b"POST /jobs HTTP/1.1\r\nHost: x\r\nContent-Length: 5\r\n\r\nhello";
        let req = read_request(&mut Cursor::new(raw.to_vec()), 1024).unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/jobs");
        assert_eq!(req.body, b"hello");
    }

    #[test]
    fn parses_bodyless_get() {
        let raw = b"GET /healthz HTTP/1.1\r\nHost: x\r\n\r\n";
        let req = read_request(&mut Cursor::new(raw.to_vec()), 1024).unwrap();
        assert_eq!(req.method, "GET");
        assert_eq!(req.path, "/healthz");
        assert!(req.body.is_empty());
    }

    #[test]
    fn oversized_body_declaration_fails_fast() {
        let raw = b"POST /jobs HTTP/1.1\r\nContent-Length: 999999\r\n\r\n";
        match read_request(&mut Cursor::new(raw.to_vec()), 1024) {
            Err(HttpError::TooLarge) => {}
            other => panic!("expected TooLarge, got {other:?}"),
        }
    }

    #[test]
    fn oversized_body_is_drained_before_the_413() {
        let mut raw = b"POST /jobs HTTP/1.1\r\nContent-Length: 2000\r\n\r\n".to_vec();
        raw.extend(vec![b'x'; 2000]);
        let len = raw.len() as u64;
        let mut cur = Cursor::new(raw);
        match read_request(&mut cur, 1024) {
            Err(HttpError::TooLarge) => {}
            other => panic!("expected TooLarge, got {other:?}"),
        }
        assert_eq!(cur.position(), len, "declared body must be consumed");
    }

    #[test]
    fn truncated_body_is_a_bad_request() {
        let raw = b"POST /jobs HTTP/1.1\r\nContent-Length: 10\r\n\r\nshort";
        match read_request(&mut Cursor::new(raw.to_vec()), 1024) {
            Err(HttpError::BadRequest(_)) => {}
            other => panic!("expected BadRequest, got {other:?}"),
        }
    }

    #[test]
    fn header_section_is_capped() {
        let mut raw = b"GET / HTTP/1.1\r\n".to_vec();
        raw.extend(vec![b'a'; MAX_HEAD_BYTES + 16]);
        match read_request(&mut Cursor::new(raw), 1024) {
            Err(HttpError::BadRequest(msg)) => assert!(msg.contains("too large"), "{msg}"),
            other => panic!("expected BadRequest, got {other:?}"),
        }
    }

    #[test]
    fn response_has_framing_headers() {
        let mut out = Vec::new();
        write_response(&mut out, 200, "application/json", b"{}").unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("HTTP/1.1 200 OK\r\n"), "{text}");
        assert!(text.contains("content-length: 2\r\n"));
        assert!(text.contains("connection: close\r\n"));
        assert!(text.ends_with("\r\n\r\n{}"));
    }
}
