//! Miniature property-testing framework.
//!
//! `proptest` is not in the offline crate set, so this module provides
//! the pieces the test suites need: a seeded case runner with failure
//! reporting, and approximate-equality helpers used across the
//! numeric tests.

use crate::prng::Rng;

/// Run `cases` randomized property checks. `generate` draws a case
/// from the seeded RNG; `property` returns `Err(description)` on
/// violation. Panics (test failure) with the case number, seed and
/// description so the exact failing case can be replayed.
pub fn check_prop<T: std::fmt::Debug>(
    name: &str,
    cases: usize,
    seed: u64,
    mut generate: impl FnMut(&mut Rng) -> T,
    mut property: impl FnMut(&T) -> Result<(), String>,
) {
    let mut rng = Rng::seeded(seed);
    for case in 0..cases {
        let input = generate(&mut rng);
        if let Err(msg) = property(&input) {
            panic!(
                "property `{name}` failed on case {case} (seed {seed}): {msg}\ninput: {input:?}"
            );
        }
    }
}

/// Absolute-or-relative closeness: `|a−b| ≤ atol + rtol·max(|a|,|b|)`.
pub fn close(a: f64, b: f64, rtol: f64, atol: f64) -> bool {
    let diff = (a - b).abs();
    diff <= atol + rtol * a.abs().max(b.abs())
}

/// Assert two slices are elementwise close; reports the worst index.
pub fn assert_slices_close(a: &[f64], b: &[f64], rtol: f64, atol: f64, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    let mut worst = (0usize, 0.0f64);
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        let d = (x - y).abs();
        if d > worst.1 {
            worst = (i, d);
        }
        assert!(
            close(x, y, rtol, atol),
            "{what}: index {i}: {x} vs {y} (|Δ|={d:.3e}); worst so far idx {} |Δ|={:.3e}",
            worst.0,
            worst.1
        );
    }
}

/// Max elementwise absolute difference between two slices.
pub fn max_abs_diff(a: &[f64], b: &[f64]) -> f64 {
    a.iter()
        .zip(b)
        .map(|(&x, &y)| (x - y).abs())
        .fold(0.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn close_semantics() {
        assert!(close(1.0, 1.0 + 1e-13, 1e-12, 0.0));
        assert!(!close(1.0, 1.1, 1e-12, 0.0));
        assert!(close(0.0, 1e-15, 0.0, 1e-14));
    }

    #[test]
    fn prop_runner_passes() {
        check_prop("sum-commutes", 50, 1, |r| (r.uniform(), r.uniform()), |&(a, b)| {
            if (a + b - (b + a)).abs() < 1e-15 {
                Ok(())
            } else {
                Err("not commutative".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property `always-fails` failed")]
    fn prop_runner_reports_failure() {
        check_prop("always-fails", 5, 2, |r| r.uniform(), |_| Err("nope".into()));
    }
}
