//! # fgc-gw — Fast Gradient Computation for Gromov-Wasserstein distance
//!
//! Full-stack reproduction of *"Fast Gradient Computation for
//! Gromov-Wasserstein Distance"* (Zhang, Wang, Fan, Wu, Zhang; 2024).
//!
//! The library is organised in three layers:
//!
//! * **Numeric core** ([`fgc`], [`sinkhorn`], [`gw`], [`grid`],
//!   [`linalg`]) — the paper's contribution: the `O(k²N)` dynamic-
//!   programming operator for `y = (L + Lᵀ)x` on uniform grids, the
//!   resulting `O(N²)` gradient `D_X Γ D_Y`, and the entropic
//!   mirror-descent solvers for GW / FGW / UGW plus fixed-support
//!   barycenters. A dense `O(N³)` baseline (`fgc::naive`) mirrors the
//!   paper's "Original" Eigen implementation for every experiment.
//! * **Runtime** ([`runtime`]) — loads AOT-compiled JAX/Pallas
//!   artifacts (HLO text produced by `python/compile/aot.py`) and
//!   executes them on the PJRT CPU client via the `xla` crate. Python
//!   never runs on the request path.
//! * **Coordinator** ([`coordinator`]) — an alignment service: a
//!   variant-sharded bounded queue with per-shard backpressure and a
//!   global admission budget, a router that picks native-FGC /
//!   native-naive / native-lowrank / PJRT backends per job, workers
//!   that pin to a shard and serve same-variant bursts from warm
//!   batched workspaces (stealing from the longest shard when theirs
//!   runs dry), and latency/throughput/warm-hit metrics. The
//!   [`server`] module puts a std-only TCP/HTTP front-end over it
//!   (`POST /jobs`, Prometheus-text `GET /metrics`).
//!
//! Supporting substrates built from scratch (the offline environment
//! vendors only `xla` + `anyhow`, both optional behind the `pjrt`
//! feature): [`parallel`] (a std-only scoped chunked-work engine that
//! drives every hot kernel — Sinkhorn sweeps, FGC scans, the dense
//! baseline — with a per-job thread budget), [`prng`]
//! (SplitMix64/xoshiro256++), [`linalg`] (dense row-major matrices),
//! [`config`] (key=value config files), [`cli`] (argument parsing),
//! [`bench_util`] (timing + log-log complexity fits) and [`testutil`]
//! (a miniature property-testing framework).
//!
//! ## Quickstart
//!
//! ```no_run
//! use fgc_gw::gw::{EntropicGw, GwConfig, GradientKind};
//! use fgc_gw::data::random_distribution;
//! use fgc_gw::prng::Rng;
//!
//! let mut rng = Rng::seeded(7);
//! let u = random_distribution(&mut rng, 500);
//! let v = random_distribution(&mut rng, 500);
//! let cfg = GwConfig { epsilon: 2e-3, ..GwConfig::default() };
//! let solver = EntropicGw::grid_1d(500, 500, 1, cfg);
//! let sol = solver.solve(&u, &v, GradientKind::Fgc).unwrap();
//! println!("GW² = {}", sol.objective);
//! ```

// Index-based loops intentionally mirror the paper's recurrences, and
// the raw-slice kernel signatures trade arity for zero allocation.
#![allow(clippy::needless_range_loop, clippy::too_many_arguments)]

pub mod bench_util;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod error;
pub mod fgc;
pub mod grid;
pub mod gw;
pub mod linalg;
pub mod parallel;
pub mod prng;
pub mod runtime;
pub mod scalar;
pub mod server;
pub mod sinkhorn;
pub mod testutil;

pub use error::{Error, Result};
