//! Random distributions on uniform grids (paper §4.1, §4.2).

use crate::linalg::normalize_l1;
use crate::prng::Rng;

/// 1D random distribution: `u_i ~ U[0,1]`, normalized to sum 1
/// (paper §4.1 construction).
pub fn random_distribution(rng: &mut Rng, n: usize) -> Vec<f64> {
    let mut u = rng.uniform_vec(n);
    normalize_l1(&mut u).expect("positive uniform mass");
    u
}

/// 2D random distribution on an `n×n` grid, flattened row-major
/// (paper §4.2): `N = n²` i.i.d. uniforms, normalized.
pub fn random_distribution_2d(rng: &mut Rng, n: usize) -> Vec<f64> {
    random_distribution(rng, n * n)
}

/// 3D random distribution on an `n×n×n` grid, flattened
/// `(z·n + y)·n + x`: `N = n³` i.i.d. uniforms, normalized.
pub fn random_distribution_3d(rng: &mut Rng, n: usize) -> Vec<f64> {
    random_distribution(rng, n * n * n)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn normalized_and_positive() {
        let mut rng = Rng::seeded(1);
        let u = random_distribution(&mut rng, 100);
        assert_eq!(u.len(), 100);
        assert!((u.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert!(u.iter().all(|&x| x > 0.0));
    }

    #[test]
    fn reproducible() {
        let a = random_distribution(&mut Rng::seeded(9), 50);
        let b = random_distribution(&mut Rng::seeded(9), 50);
        assert_eq!(a, b);
    }

    #[test]
    fn two_d_size() {
        let mut rng = Rng::seeded(2);
        let u = random_distribution_2d(&mut rng, 30);
        assert_eq!(u.len(), 900);
    }
}
