//! Grayscale image container + FGW feature costs (paper §4.4).

use crate::error::{Error, Result};
use crate::linalg::Mat;

/// A square grayscale image with values in `[0,1]`, row-major.
#[derive(Clone, Debug, PartialEq)]
pub struct GrayImage {
    /// Side length.
    pub n: usize,
    /// Row-major pixel values.
    pub pixels: Vec<f64>,
}

impl GrayImage {
    /// Construct (shape-checked).
    pub fn new(n: usize, pixels: Vec<f64>) -> Result<Self> {
        if pixels.len() != n * n {
            return Err(Error::shape(
                "GrayImage::new",
                format!("{}", n * n),
                format!("{}", pixels.len()),
            ));
        }
        Ok(GrayImage { n, pixels })
    }

    /// All-zero image.
    pub fn zeros(n: usize) -> Self {
        GrayImage {
            n,
            pixels: vec![0.0; n * n],
        }
    }

    /// Pixel at `(row, col)`.
    #[inline]
    pub fn get(&self, r: usize, c: usize) -> f64 {
        self.pixels[r * self.n + c]
    }

    /// Mutable pixel.
    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f64) {
        self.pixels[r * self.n + c] = v;
    }

    /// Normalize pixel mass into a probability distribution over the
    /// grid (adding a small floor so Sinkhorn rows never zero out).
    pub fn to_distribution(&self, floor: f64) -> Vec<f64> {
        let mut w: Vec<f64> = self.pixels.iter().map(|&p| p + floor).collect();
        crate::linalg::normalize_l1(&mut w).expect("floored mass is positive");
        w
    }

    /// Area-averaged subsampling from an arbitrary `rows×cols` buffer
    /// to an `n×n` image (the horse task subsamples 450×300 frames,
    /// §4.4.2).
    pub fn subsample(rows: usize, cols: usize, data: &[f64], n: usize) -> Result<Self> {
        if data.len() != rows * cols {
            return Err(Error::shape(
                "GrayImage::subsample",
                format!("{}", rows * cols),
                format!("{}", data.len()),
            ));
        }
        let mut img = GrayImage::zeros(n);
        for r in 0..n {
            for c in 0..n {
                // source cell range (area average)
                let r0 = r * rows / n;
                let r1 = (((r + 1) * rows).div_ceil(n)).min(rows).max(r0 + 1);
                let c0 = c * cols / n;
                let c1 = (((c + 1) * cols).div_ceil(n)).min(cols).max(c0 + 1);
                let mut acc = 0.0;
                for rr in r0..r1 {
                    for cc in c0..c1 {
                        acc += data[rr * cols + cc];
                    }
                }
                img.set(r, c, acc / ((r1 - r0) * (c1 - c0)) as f64);
            }
        }
        Ok(img)
    }

    /// Render as ASCII art (for example binaries / debugging).
    pub fn ascii(&self) -> String {
        const RAMP: &[u8] = b" .:-=+*#%@";
        let mut s = String::with_capacity(self.n * (self.n + 1));
        for r in 0..self.n {
            for c in 0..self.n {
                let v = self.get(r, c).clamp(0.0, 1.0);
                let idx = ((v * (RAMP.len() - 1) as f64).round()) as usize;
                s.push(RAMP[idx] as char);
            }
            s.push('\n');
        }
        s
    }
}

/// FGW feature cost between two images: `c_ip = |gray_i − gray_p|`
/// over flattened pixels (§4.4.1 "difference in the pixel gray
/// levels").
pub fn feature_cost_gray(source: &GrayImage, target: &GrayImage) -> Mat {
    Mat::from_fn(
        source.pixels.len(),
        target.pixels.len(),
        |i, p| (source.pixels[i] - target.pixels[p]).abs(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn distribution_sums_to_one() {
        let mut img = GrayImage::zeros(4);
        img.set(1, 2, 0.8);
        let w = img.to_distribution(1e-6);
        assert!((w.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert!(w.iter().all(|&x| x > 0.0));
    }

    #[test]
    fn subsample_preserves_mean() {
        let rows = 12;
        let cols = 9;
        let data: Vec<f64> = (0..rows * cols).map(|i| (i % 7) as f64 / 7.0).collect();
        let img = GrayImage::subsample(rows, cols, &data, 3).unwrap();
        let src_mean: f64 = data.iter().sum::<f64>() / data.len() as f64;
        let dst_mean: f64 = img.pixels.iter().sum::<f64>() / 9.0;
        assert!((src_mean - dst_mean).abs() < 0.05, "{src_mean} vs {dst_mean}");
    }

    #[test]
    fn feature_cost_zero_on_identical() {
        let mut img = GrayImage::zeros(3);
        img.set(0, 0, 0.5);
        let c = feature_cost_gray(&img, &img);
        for i in 0..9 {
            assert_eq!(c[(i, i)], 0.0);
        }
    }

    #[test]
    fn ascii_renders() {
        let mut img = GrayImage::zeros(2);
        img.set(0, 0, 1.0);
        let art = img.ascii();
        assert!(art.starts_with('@'));
        assert_eq!(art.lines().count(), 2);
    }
}
