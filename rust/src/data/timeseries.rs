//! Two-hump time series (paper §4.3).
//!
//! "Consider a series in [0,1] that consists of two humps with heights
//! of 0.5 and 0.8. We construct the other series by moving the humps
//! around." The humps are smooth bumps (raised cosines) so alignment
//! is well-posed; positions/widths are the knobs the experiment moves.

use crate::linalg::Mat;

/// Parameters of a two-hump series.
#[derive(Clone, Copy, Debug)]
pub struct TwoHumpSpec {
    /// Center of the first hump (height 0.5), in `[0,1]`.
    pub center1: f64,
    /// Center of the second hump (height 0.8), in `[0,1]`.
    pub center2: f64,
    /// Half-width of each hump.
    pub width: f64,
}

impl Default for TwoHumpSpec {
    fn default() -> Self {
        TwoHumpSpec {
            center1: 0.3,
            center2: 0.7,
            width: 0.08,
        }
    }
}

/// Sample the series at `n` uniform points on `[0,1]`: the signal
/// strength at each sampling instant.
pub fn two_hump_series(spec: &TwoHumpSpec, n: usize) -> Vec<f64> {
    (0..n)
        .map(|i| {
            let t = if n > 1 { i as f64 / (n - 1) as f64 } else { 0.0 };
            bump(t, spec.center1, spec.width) * 0.5 + bump(t, spec.center2, spec.width) * 0.8
        })
        .collect()
}

/// Raised-cosine bump: 1 at the center, smoothly 0 outside ±width.
fn bump(t: f64, center: f64, width: f64) -> f64 {
    let d = (t - center).abs();
    if d >= width {
        0.0
    } else {
        0.5 * (1.0 + (std::f64::consts::PI * d / width).cos())
    }
}

/// FGW feature cost between two series: `c_ip = |s_i − t_p|`
/// (signal-strength difference, §4.3).
pub fn feature_cost_series(source: &[f64], target: &[f64]) -> Mat {
    Mat::from_fn(source.len(), target.len(), |i, p| {
        (source[i] - target[p]).abs()
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn has_two_humps_with_expected_heights() {
        let s = two_hump_series(&TwoHumpSpec::default(), 1001);
        // peak near 0.3 → index 300 ± few
        let p1 = s[290..311].iter().cloned().fold(0.0, f64::max);
        let p2 = s[690..711].iter().cloned().fold(0.0, f64::max);
        assert!((p1 - 0.5).abs() < 1e-3, "p1={p1}");
        assert!((p2 - 0.8).abs() < 1e-3, "p2={p2}");
        // zero between humps
        assert!(s[500].abs() < 1e-12);
    }

    #[test]
    fn cost_matrix_shape_and_symmetric_on_identical() {
        let s = two_hump_series(&TwoHumpSpec::default(), 50);
        let c = feature_cost_series(&s, &s);
        assert_eq!(c.shape(), (50, 50));
        for i in 0..50 {
            assert_eq!(c[(i, i)], 0.0);
        }
    }

    #[test]
    fn moving_humps_changes_cost() {
        let a = two_hump_series(&TwoHumpSpec::default(), 64);
        let b = two_hump_series(
            &TwoHumpSpec {
                center1: 0.2,
                center2: 0.8,
                width: 0.08,
            },
            64,
        );
        assert_ne!(a, b);
        let c = feature_cost_series(&a, &b);
        assert!(c.max() > 0.1);
    }
}
