//! Parametric running-horse silhouette (paper §4.4.2 substitute).
//!
//! The paper aligns two 450×300 frames of a running horse showing
//! "complex deformation". Offline we synthesize frames: a body
//! ellipse, neck + head, tail, and four legs whose joint angles are
//! functions of the gait `phase` — so two phases give two smoothly
//! deformed silhouettes with matching topology, which is exactly what
//! the alignment experiment needs (DESIGN.md §4).

use super::image::GrayImage;
use crate::error::Result;

/// Render a frame at the native 450-wide × 300-high resolution used
/// by the paper, then subsample to `n×n` grayscale. `phase ∈ [0,1)`
/// is the gait cycle position.
pub fn horse_frame(phase: f64, n: usize) -> Result<GrayImage> {
    const W: usize = 450;
    const H: usize = 300;
    let mut canvas = vec![0.0f64; W * H];

    // Body: ellipse centered mid-frame, bobbing slightly with phase.
    let bob = 8.0 * (2.0 * std::f64::consts::PI * phase).sin();
    let (bcx, bcy) = (225.0, 150.0 + bob);
    fill_ellipse(&mut canvas, W, H, bcx, bcy, 95.0, 42.0, 0.0);

    // Neck + head: angled forward, nodding with the gait.
    let nod = 0.15 * (2.0 * std::f64::consts::PI * phase).cos();
    let neck_ang = -0.9 + nod;
    let (nx, ny) = (bcx + 80.0, bcy - 20.0);
    let (hx, hy) = (nx + 55.0 * neck_ang.cos(), ny + 55.0 * neck_ang.sin());
    thick_line(&mut canvas, W, H, nx, ny, hx, hy, 16.0);
    fill_ellipse(&mut canvas, W, H, hx + 18.0, hy - 4.0, 26.0, 13.0, -0.35);

    // Tail.
    let (tx, ty) = (bcx - 92.0, bcy - 18.0);
    let sway = 0.35 * (2.0 * std::f64::consts::PI * phase + 1.2).sin();
    thick_line(
        &mut canvas,
        W,
        H,
        tx,
        ty,
        tx - 45.0 * (0.7 + sway).cos(),
        ty + 45.0 * (0.7 + sway).sin(),
        7.0,
    );

    // Four legs: two-segment limbs with phase-offset gait angles —
    // this is the "complex deformation" between frames.
    let hips = [(bcx - 65.0, bcy + 30.0), (bcx - 45.0, bcy + 34.0)];
    let shoulders = [(bcx + 55.0, bcy + 30.0), (bcx + 72.0, bcy + 26.0)];
    for (idx, &(jx, jy)) in hips.iter().chain(shoulders.iter()).enumerate() {
        let leg_phase = phase + idx as f64 * 0.25;
        let swing = 0.55 * (2.0 * std::f64::consts::PI * leg_phase).sin();
        let knee_bend = 0.45 * (2.0 * std::f64::consts::PI * leg_phase + 0.8).cos().max(0.0);
        let upper_ang = std::f64::consts::FRAC_PI_2 + swing;
        let (kx, ky) = (jx + 42.0 * upper_ang.cos(), jy + 42.0 * upper_ang.sin());
        let lower_ang = upper_ang + knee_bend;
        let (fx, fy) = (kx + 40.0 * lower_ang.cos(), ky + 40.0 * lower_ang.sin());
        thick_line(&mut canvas, W, H, jx, jy, kx, ky, 10.0);
        thick_line(&mut canvas, W, H, kx, ky, fx, fy, 8.0);
    }

    GrayImage::subsample(H, W, &transpose_to_rows(&canvas, W, H), n)
}

/// Canvas is addressed `(x, y)` column-major below; convert to the
/// row-major `rows×cols = H×W` layout `subsample` expects.
fn transpose_to_rows(canvas: &[f64], w: usize, h: usize) -> Vec<f64> {
    let mut out = vec![0.0; w * h];
    for y in 0..h {
        for x in 0..w {
            out[y * w + x] = canvas[x * h + y];
        }
    }
    out
}

fn fill_ellipse(canvas: &mut [f64], w: usize, h: usize, cx: f64, cy: f64, rx: f64, ry: f64, rot: f64) {
    let (s, c) = rot.sin_cos();
    let x0 = ((cx - rx - ry).floor().max(0.0)) as usize;
    let x1 = ((cx + rx + ry).ceil().min(w as f64 - 1.0)) as usize;
    let y0 = ((cy - rx - ry).floor().max(0.0)) as usize;
    let y1 = ((cy + rx + ry).ceil().min(h as f64 - 1.0)) as usize;
    for x in x0..=x1 {
        for y in y0..=y1 {
            let dx = x as f64 - cx;
            let dy = y as f64 - cy;
            let u = (dx * c + dy * s) / rx;
            let v = (-dx * s + dy * c) / ry;
            if u * u + v * v <= 1.0 {
                canvas[x * h + y] = 1.0;
            }
        }
    }
}

fn thick_line(canvas: &mut [f64], w: usize, h: usize, x0: f64, y0: f64, x1: f64, y1: f64, thick: f64) {
    let len = ((x1 - x0).powi(2) + (y1 - y0).powi(2)).sqrt().max(1.0);
    let steps = (len * 2.0) as usize;
    let r = thick / 2.0;
    for t in 0..=steps {
        let f = t as f64 / steps as f64;
        let cx = x0 + f * (x1 - x0);
        let cy = y0 + f * (y1 - y0);
        let px0 = ((cx - r).floor().max(0.0)) as usize;
        let px1 = ((cx + r).ceil().min(w as f64 - 1.0)) as usize;
        let py0 = ((cy - r).floor().max(0.0)) as usize;
        let py1 = ((cy + r).ceil().min(h as f64 - 1.0)) as usize;
        for x in px0..=px1 {
            for y in py0..=py1 {
                let dx = x as f64 - cx;
                let dy = y as f64 - cy;
                if dx * dx + dy * dy <= r * r {
                    canvas[x * h + y] = 1.0;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frame_has_silhouette() {
        let img = horse_frame(0.0, 60).unwrap();
        let mass: f64 = img.pixels.iter().sum();
        // The silhouette covers a nontrivial but minor fraction.
        let frac = mass / (60.0 * 60.0);
        assert!(frac > 0.03 && frac < 0.6, "coverage={frac}");
    }

    #[test]
    fn different_phases_deform() {
        let a = horse_frame(0.0, 40).unwrap();
        let b = horse_frame(0.45, 40).unwrap();
        let diff: f64 = a
            .pixels
            .iter()
            .zip(&b.pixels)
            .map(|(&x, &y)| (x - y).abs())
            .sum();
        assert!(diff > 1.0, "frames too similar: {diff}");
        // but topology/scale match: total ink similar
        let ma: f64 = a.pixels.iter().sum();
        let mb: f64 = b.pixels.iter().sum();
        assert!((ma - mb).abs() / ma < 0.35, "ink {ma} vs {mb}");
    }

    #[test]
    fn deterministic() {
        let a = horse_frame(0.2, 32).unwrap();
        let b = horse_frame(0.2, 32).unwrap();
        assert_eq!(a, b);
    }
}
