//! Synthetic handwritten-digit glyph + exact isometries (paper §4.4.1).
//!
//! The paper aligns an MNIST digit "3" against translated / rotated /
//! reflected copies to show FGC preserves FGW's invariances. MNIST is
//! not available offline, so we rasterize a stroke-drawn "3" at 28×28
//! with soft (anti-aliased) edges — the experiment only needs a sparse
//! grayscale glyph and its exact grid isometries, which
//! [`transform_image`] provides (rotation is by 90° multiples so the
//! transform is an exact permutation of grid points).

use super::image::GrayImage;

/// The grid isometries of §4.4.1.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Transform {
    /// Shift by (rows, cols), zero-filling.
    Translate(isize, isize),
    /// Rotate 90° counter-clockwise `quarters` times.
    Rotate90(u8),
    /// Mirror left-right.
    ReflectHorizontal,
    /// Mirror top-bottom.
    ReflectVertical,
}

/// Rasterize a "3"-like glyph at `n×n` (28 matches MNIST). Drawn as
/// two stacked arcs with a soft brush.
pub fn digit_three(n: usize) -> GrayImage {
    let mut img = GrayImage::zeros(n);
    let s = n as f64;
    // Two arcs approximating the strokes of a 3: upper bowl and lower
    // bowl, both open to the left. Parametrized by angle.
    let brush = s * 0.06;
    let centers = [(0.36 * s, 0.5 * s), (0.64 * s, 0.5 * s)];
    let radius = 0.17 * s;
    for (cy, cx) in centers {
        let steps = (8.0 * s) as usize;
        for t in 0..=steps {
            // arc from -100° to +100° (opening to the left)
            let ang = -1.85 + 3.7 * (t as f64 / steps as f64);
            let y = cy + radius * ang.sin();
            let x = cx + radius * ang.cos();
            stamp(&mut img, y, x, brush);
        }
    }
    img
}

/// Soft circular brush stamp with Gaussian falloff.
fn stamp(img: &mut GrayImage, y: f64, x: f64, brush: f64) {
    let n = img.n as isize;
    let rad = (brush * 2.0).ceil() as isize;
    let (yi, xi) = (y.round() as isize, x.round() as isize);
    for dr in -rad..=rad {
        for dc in -rad..=rad {
            let (r, c) = (yi + dr, xi + dc);
            if r < 0 || c < 0 || r >= n || c >= n {
                continue;
            }
            let dy = r as f64 - y;
            let dx = c as f64 - x;
            let d2 = dy * dy + dx * dx;
            let v = (-d2 / (brush * brush)).exp();
            let cur = img.get(r as usize, c as usize);
            img.set(r as usize, c as usize, (cur + v).min(1.0));
        }
    }
}

/// Apply an exact grid isometry (or translation) to an image.
pub fn transform_image(img: &GrayImage, t: Transform) -> GrayImage {
    let n = img.n;
    let mut out = GrayImage::zeros(n);
    match t {
        Transform::Translate(dr, dc) => {
            for r in 0..n {
                for c in 0..n {
                    let (sr, sc) = (r as isize - dr, c as isize - dc);
                    if sr >= 0 && sc >= 0 && (sr as usize) < n && (sc as usize) < n {
                        out.set(r, c, img.get(sr as usize, sc as usize));
                    }
                }
            }
        }
        Transform::Rotate90(q) => {
            let mut cur = img.clone();
            for _ in 0..(q % 4) {
                let mut next = GrayImage::zeros(n);
                for r in 0..n {
                    for c in 0..n {
                        // CCW: (r, c) ← (c, n−1−r)
                        next.set(n - 1 - c, r, cur.get(r, c));
                    }
                }
                cur = next;
            }
            out = cur;
        }
        Transform::ReflectHorizontal => {
            for r in 0..n {
                for c in 0..n {
                    out.set(r, n - 1 - c, img.get(r, c));
                }
            }
        }
        Transform::ReflectVertical => {
            for r in 0..n {
                for c in 0..n {
                    out.set(n - 1 - r, c, img.get(r, c));
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn glyph_has_ink() {
        let img = digit_three(28);
        let mass: f64 = img.pixels.iter().sum();
        assert!(mass > 10.0, "mass={mass}");
        assert!(img.pixels.iter().all(|&p| (0.0..=1.0).contains(&p)));
    }

    #[test]
    fn rotation_four_times_is_identity() {
        let img = digit_three(28);
        let r4 = transform_image(&img, Transform::Rotate90(4));
        assert_eq!(img, r4);
    }

    #[test]
    fn reflection_twice_is_identity() {
        let img = digit_three(28);
        let rr = transform_image(
            &transform_image(&img, Transform::ReflectHorizontal),
            Transform::ReflectHorizontal,
        );
        assert_eq!(img, rr);
    }

    #[test]
    fn translation_preserves_interior_mass() {
        let img = digit_three(28);
        let t = transform_image(&img, Transform::Translate(2, -1));
        // glyph is centered; a 2px shift loses at most the faint
        // Gaussian brush tails near the border (≈1% of the ink).
        let m0: f64 = img.pixels.iter().sum();
        let m1: f64 = t.pixels.iter().sum();
        assert!((m0 - m1).abs() / m0 < 0.02, "{m0} vs {m1}");
    }

    #[test]
    fn rotation_permutes_pixels() {
        let img = digit_three(16);
        let rot = transform_image(&img, Transform::Rotate90(1));
        let mut a = img.pixels.clone();
        let mut b = rot.pixels.clone();
        a.sort_by(f64::total_cmp);
        b.sort_by(f64::total_cmp);
        assert_eq!(a, b); // exact permutation — isometry on the grid
    }
}
