//! Workload generators for every experiment in the paper (§4).
//!
//! The paper's external resources are substituted with synthetic
//! equivalents that exercise the same code paths (DESIGN.md §4):
//! MNIST's digit-3 bitmap → a stroke-rasterized glyph; the
//! running-horse video frames → a parametric articulated silhouette.
//! Random distributions and the two-hump time series follow the
//! paper's construction directly.

mod digits;
mod horse;
mod image;
mod pgm;
mod random;
mod timeseries;

pub use digits::{digit_three, transform_image, Transform};
pub use horse::horse_frame;
pub use image::{feature_cost_gray, GrayImage};
pub use pgm::{read_pgm, write_pgm};
pub use random::{random_distribution, random_distribution_2d, random_distribution_3d};
pub use timeseries::{feature_cost_series, two_hump_series, TwoHumpSpec};
