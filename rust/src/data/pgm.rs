//! Minimal PGM (portable graymap) writer/reader — lets examples dump
//! transport plans and silhouettes as viewable images (the paper's
//! Figures 4/5 visuals) without an image crate.

use crate::error::{Error, Result};
use crate::linalg::Mat;
use std::io::Write;
use std::path::Path;

/// Write a matrix as an 8-bit binary PGM, min-max normalized.
pub fn write_pgm(path: &Path, m: &Mat) -> Result<()> {
    let (rows, cols) = m.shape();
    if rows == 0 || cols == 0 {
        return Err(Error::Invalid("empty matrix".into()));
    }
    let lo = m.min();
    let hi = m.max();
    let span = (hi - lo).max(1e-300);
    let mut buf = Vec::with_capacity(rows * cols + 64);
    write!(buf, "P5\n{cols} {rows}\n255\n").expect("vec write");
    for &x in m.as_slice() {
        let v = ((x - lo) / span * 255.0).round().clamp(0.0, 255.0) as u8;
        buf.push(v);
    }
    std::fs::write(path, buf).map_err(|e| Error::Io(format!("writing {}", path.display()), e))
}

/// Read a binary (`P5`) PGM back into a matrix scaled to `[0,1]`.
pub fn read_pgm(path: &Path) -> Result<Mat> {
    let data =
        std::fs::read(path).map_err(|e| Error::Io(format!("reading {}", path.display()), e))?;
    let header_err = || Error::Invalid(format!("{}: not a P5 PGM", path.display()));
    // Parse "P5\n<w> <h>\n<max>\n" allowing arbitrary whitespace.
    let mut fields = Vec::new();
    let mut idx = 0;
    while fields.len() < 4 && idx < data.len() {
        while idx < data.len() && data[idx].is_ascii_whitespace() {
            idx += 1;
        }
        if idx < data.len() && data[idx] == b'#' {
            while idx < data.len() && data[idx] != b'\n' {
                idx += 1;
            }
            continue;
        }
        let start = idx;
        while idx < data.len() && !data[idx].is_ascii_whitespace() {
            idx += 1;
        }
        fields.push(std::str::from_utf8(&data[start..idx]).map_err(|_| header_err())?);
    }
    if fields.len() != 4 || fields[0] != "P5" {
        return Err(header_err());
    }
    let cols: usize = fields[1].parse().map_err(|_| header_err())?;
    let rows: usize = fields[2].parse().map_err(|_| header_err())?;
    let maxv: f64 = fields[3].parse().map_err(|_| header_err())?;
    idx += 1; // single whitespace after maxval
    let pixels = &data[idx..];
    if pixels.len() < rows * cols {
        return Err(Error::Invalid(format!(
            "{}: truncated pixel data",
            path.display()
        )));
    }
    Mat::from_vec(
        rows,
        cols,
        pixels[..rows * cols]
            .iter()
            .map(|&b| b as f64 / maxv)
            .collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Rng;

    #[test]
    fn roundtrip() {
        let mut rng = Rng::seeded(5);
        let m = Mat::from_fn(13, 17, |_, _| rng.uniform());
        let path = std::env::temp_dir().join("fgcgw_test_roundtrip.pgm");
        write_pgm(&path, &m).unwrap();
        let back = read_pgm(&path).unwrap();
        assert_eq!(back.shape(), (13, 17));
        // 8-bit quantization + min-max normalization ⇒ coarse match
        for (a, b) in m.as_slice().iter().zip(back.as_slice()) {
            let a_norm = (a - m.min()) / (m.max() - m.min());
            assert!((a_norm - b).abs() < 1.0 / 128.0, "{a_norm} vs {b}");
        }
    }

    #[test]
    fn rejects_garbage() {
        let path = std::env::temp_dir().join("fgcgw_test_garbage.pgm");
        std::fs::write(&path, b"not a pgm at all").unwrap();
        assert!(read_pgm(&path).is_err());
        assert!(write_pgm(&path, &Mat::zeros(0, 0)).is_err());
    }

    #[test]
    fn constant_image_no_nan() {
        let m = Mat::full(4, 4, 0.7);
        let path = std::env::temp_dir().join("fgcgw_test_const.pgm");
        write_pgm(&path, &m).unwrap();
        let back = read_pgm(&path).unwrap();
        assert!(back.all_finite());
    }
}
