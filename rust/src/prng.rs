//! Deterministic pseudo-random number generation.
//!
//! The offline crate set has no `rand`, so this module provides the
//! substrate: a SplitMix64 seeder feeding a xoshiro256++ generator
//! (Blackman & Vigna). All experiments in the repo draw through this
//! module, so every table/figure is reproducible from a seed.

/// SplitMix64 step — used to expand a single `u64` seed into the
/// 256-bit xoshiro state, and available on its own for cheap hashing.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256++ PRNG. Period 2²⁵⁶−1, passes BigCrush; more than
/// adequate for workload generation.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Construct from a 64-bit seed (expanded via SplitMix64).
    pub fn seeded(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform `f64` in `[0, 1)` (53-bit mantissa construction).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `f64` in `[lo, hi)`.
    #[inline]
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in `[0, n)` via Lemire's multiply-shift method
    /// (with rejection to remove modulo bias).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "Rng::below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Standard normal via Box–Muller (polar form avoided for
    /// determinism of call counts: always consumes two uniforms).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.uniform().max(f64::MIN_POSITIVE);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Vector of `n` uniforms in `[0,1)`.
    pub fn uniform_vec(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.uniform()).collect()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::seeded(42);
        let mut b = Rng::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::seeded(1);
        let mut b = Rng::seeded(2);
        let same = (0..32).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn uniform_range_and_mean() {
        let mut r = Rng::seeded(7);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.uniform();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Rng::seeded(3);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[r.below(5) as usize] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "counts={counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seeded(9);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seeded(11);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }
}
