//! Configuration files: a small `key = value` format with `#`
//! comments and `[section]` headers (serde/toml are not in the
//! offline crate set; this covers what the launcher needs).
//!
//! ```text
//! # fgc-gw service config
//! [service]
//! native_workers = 2
//! queue_capacity = 64
//! enable_pjrt = false
//!
//! [coordinator]
//! shards = 0         # variant shards in the native queue (0 = auto)
//!
//! [solver]
//! epsilon = 0.002
//! outer_iters = 10
//! threads = 1        # per-job kernel threads (0 = all cores)
//! backend = auto     # auto | fgc | naive | lowrank (router override)
//! lowrank_tol = 0    # ACA residual tolerance (0 = derive from ε)
//! ```

use crate::error::{Error, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// Parsed configuration: `section.key → value` (keys outside any
/// section live under `""`).
#[derive(Clone, Debug, Default)]
pub struct Config {
    values: BTreeMap<String, String>,
}

impl Config {
    /// Parse from text.
    pub fn parse(text: &str) -> Result<Self> {
        let mut values = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if line.starts_with('[') {
                if !line.ends_with(']') {
                    return Err(Error::Config(format!(
                        "line {}: unterminated section header",
                        lineno + 1
                    )));
                }
                section = line[1..line.len() - 1].trim().to_string();
                continue;
            }
            let Some((key, value)) = line.split_once('=') else {
                return Err(Error::Config(format!(
                    "line {}: expected `key = value`, got `{line}`",
                    lineno + 1
                )));
            };
            let full_key = if section.is_empty() {
                key.trim().to_string()
            } else {
                format!("{section}.{}", key.trim())
            };
            values.insert(full_key, value.trim().to_string());
        }
        Ok(Config { values })
    }

    /// Load from a file.
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::Io(format!("reading {}", path.display()), e))?;
        Self::parse(&text)
    }

    /// Raw lookup.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    /// Typed lookup with default.
    pub fn get_or<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T> {
        match self.values.get(key) {
            None => Ok(default),
            Some(raw) => raw.parse().map_err(|_| {
                Error::Config(format!("key `{key}`: cannot parse `{raw}`"))
            }),
        }
    }

    /// Boolean lookup (`true/false/1/0/yes/no`).
    pub fn get_bool_or(&self, key: &str, default: bool) -> Result<bool> {
        match self.values.get(key).map(|s| s.as_str()) {
            None => Ok(default),
            Some("true") | Some("1") | Some("yes") => Ok(true),
            Some("false") | Some("0") | Some("no") => Ok(false),
            Some(other) => Err(Error::Config(format!("key `{key}`: bad bool `{other}`"))),
        }
    }

    /// Override a value (CLI `--set section.key=value`).
    pub fn set(&mut self, key: &str, value: &str) {
        self.values.insert(key.to_string(), value.to_string());
    }

    /// Number of entries.
    pub fn len(&self) -> usize {
        self.values.len()
    }

    /// True iff empty.
    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_comments_types() {
        let cfg = Config::parse(
            "# top\nroot_key = 7\n[service]\nnative_workers = 3 # inline\nenable_pjrt = yes\n\n[solver]\nepsilon = 0.004\n",
        )
        .unwrap();
        assert_eq!(cfg.get_or("root_key", 0usize).unwrap(), 7);
        assert_eq!(cfg.get_or("service.native_workers", 1usize).unwrap(), 3);
        assert!(cfg.get_bool_or("service.enable_pjrt", false).unwrap());
        assert_eq!(cfg.get_or("solver.epsilon", 0.0f64).unwrap(), 0.004);
        assert_eq!(cfg.get_or("missing", 42usize).unwrap(), 42);
    }

    #[test]
    fn rejects_malformed() {
        assert!(Config::parse("[unclosed\n").is_err());
        assert!(Config::parse("no equals sign\n").is_err());
        let cfg = Config::parse("x = notanumber\n").unwrap();
        assert!(cfg.get_or("x", 0u32).is_err());
        assert!(cfg.get_bool_or("x", false).is_err());
    }

    #[test]
    fn set_overrides() {
        let mut cfg = Config::parse("[a]\nb = 1\n").unwrap();
        cfg.set("a.b", "2");
        assert_eq!(cfg.get_or("a.b", 0u32).unwrap(), 2);
    }
}
