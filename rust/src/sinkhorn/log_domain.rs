//! Log-domain (stabilized) Sinkhorn.
//!
//! Works on scaled dual potentials `φ = f/ε`, `ψ = g/ε` against the
//! scaled cost `S = Π/ε`:
//!
//! ```text
//! φ_i ← log u_i − LSE_j (ψ_j − S_ij)
//! ψ_j ← log v_j − LSE_i (φ_i − S_ij)
//! Γ_ij = exp(φ_i + ψ_j − S_ij)
//! ```
//!
//! Every log-sum-exp is max-shifted, so arbitrarily small ε (the
//! paper's 0.002 with O(1) costs ⇒ exponents ≈ −1000) cannot
//! under/overflow. Zero-mass marginal entries map to `φ = −∞`, which
//! correctly zeroes the corresponding plan row/column.
//!
//! Both potential sweeps are embarrassingly row-parallel (each `φ_i`
//! reads all of `ψ` and a contiguous row of `S`; symmetrically for
//! `ψ_j` over `Sᵀ`), so the parallel blocks are bitwise identical to
//! the serial sweep for every thread count — only the convergence
//! check's error *sum* is a cross-block reduction.

use super::workspace::SinkhornWorkspace;
use super::{validate, SinkhornOptions, SinkhornResult};
use crate::error::{Error, Result};
use crate::linalg::Mat;
use crate::parallel::{self, Parallelism};
use crate::scalar::Scalar;

/// Balanced Sinkhorn with log-domain stabilization.
pub fn sinkhorn_log(
    cost: &Mat,
    u: &[f64],
    v: &[f64],
    opts: &SinkhornOptions,
) -> Result<SinkhornResult> {
    validate(cost, u, v, opts)?;
    let (m, n) = cost.shape();
    let mut ws = SinkhornWorkspace::new(m, n, Parallelism::SERIAL);
    let mut plan = Mat::zeros(m, n);
    let (iterations, marginal_error) = log_into(cost, u, v, opts, &mut ws, &mut plan)?;
    Ok(SinkhornResult {
        plan,
        iterations,
        marginal_error,
    })
}

/// Workspace form of [`sinkhorn_log`]: zero heap allocation on the
/// success path once the workspace's `Sᵀ` buffer exists (first call
/// builds it), plan written into `plan`. Returns
/// `(iterations, marginal_error)`.
pub(super) fn log_into(
    cost: &Mat,
    u: &[f64],
    v: &[f64],
    opts: &SinkhornOptions,
    ws: &mut SinkhornWorkspace,
    plan: &mut Mat,
) -> Result<(usize, f64)> {
    let (m, n) = cost.shape();
    debug_assert_eq!((ws.m, ws.n), (m, n));
    let inv_eps = 1.0 / opts.epsilon;
    let warm = ws.take_warm_duals();
    ws.ensure_kernel_t();
    let SinkhornWorkspace {
        kernel,
        kernel_t,
        a: phi,
        b: psi,
        kta,
        log_u,
        log_v,
        reduce,
        par,
        ..
    } = ws;
    let par = *par;
    let min_rows_m = parallel::min_rows_for(n.max(1));
    let min_rows_n = parallel::min_rows_for(m.max(1));

    // S = Π/ε into the workspace kernel slot; Sᵀ beside it so the ψ
    // sweep also streams contiguous rows.
    let cs = cost.as_slice();
    parallel::for_row_blocks(par, m, n, min_rows_m, kernel.as_mut_slice(), |_bl, rr, sblk| {
        let src = &cs[rr.start * n..rr.end * n];
        for (d, &c) in sblk.iter_mut().zip(src) {
            *d = c * inv_eps;
        }
    });
    let st_mat = kernel_t.as_mut().expect("ensure_kernel_t ran");
    kernel.transpose_into(st_mat)?;
    let s = &*kernel;
    let st = &*st_mat;

    for (d, &x) in log_u.iter_mut().zip(u) {
        *d = x.ln(); // ln 0 = −inf is fine
    }
    for (d, &x) in log_v.iter_mut().zip(v) {
        *d = x.ln();
    }
    phi.fill(0.0);
    if warm {
        // The seed arrives in Gibbs scaling form (positive `b`); the
        // log sweep works on potentials, so translate: `ψ = ln b`.
        for p in psi.iter_mut() {
            *p = p.ln();
        }
    } else {
        psi.fill(0.0);
    }

    let mut iterations = 0;
    for it in 0..opts.max_iters {
        iterations = it + 1;
        // φ update: rows of S are contiguous.
        {
            let (psi_r, log_u_r) = (&*psi, &*log_u);
            parallel::for_row_blocks(par, m, 1, min_rows_m, phi, |_bl, rr, pblk| {
                for (local, i) in rr.enumerate() {
                    pblk[local] = log_u_r[i] - lse_shifted(psi_r, s.row(i));
                }
            });
        }
        // ψ update: rows of Sᵀ are contiguous.
        {
            let (phi_r, log_v_r) = (&*phi, &*log_v);
            parallel::for_row_blocks(par, n, 1, min_rows_n, psi, |_bl, rr, pblk| {
                for (local, j) in rr.enumerate() {
                    pblk[local] = log_v_r[j] - lse_shifted(phi_r, st.row(j));
                }
            });
        }
        if it % opts.check_every == opts.check_every - 1 {
            // Row-marginal violation: after the ψ update columns are
            // exact; rows drift by the same mechanism as Gibbs.
            let (phi_r, psi_r) = (&*phi, &*psi);
            let err = parallel::sum_blocks(par, m, min_rows_m, reduce, |_bl, rr| {
                let mut e = 0.0;
                for i in rr {
                    e += (sum_exp_row(phi_r[i], psi_r, s.row(i)) - u[i]).abs();
                }
                e
            });
            if err < opts.tolerance {
                break;
            }
        }
    }

    let (phi_r, psi_r) = (&*phi, &*psi);
    parallel::for_row_blocks(par, m, n, min_rows_m, plan.as_mut_slice(), |_bl, rr, pblk| {
        for (local, i) in rr.enumerate() {
            let srow = s.row(i);
            let fi = phi_r[i];
            let prow = &mut pblk[local * n..(local + 1) * n];
            for ((p, &sij), &gj) in prow.iter_mut().zip(srow).zip(psi_r) {
                *p = (fi + gj - sij).exp();
            }
        }
    });
    if !plan.all_finite() {
        return Err(Error::Numeric("log sinkhorn produced non-finite plan".into()));
    }
    let marginal_error = super::marginal_error_scratch(plan, u, v, kta);
    Ok((iterations, marginal_error))
}

/// `log Σ_j exp(w_j − s_j)` with max-shift; returns −∞ on empty /
/// all −∞ input (handled by the caller via `ln u = −∞` semantics).
/// Precision-generic (`T = f64` at the solver call sites; the f32
/// serving lane runs the same max-shifted core).
#[inline]
pub(crate) fn lse_shifted<T: Scalar>(w: &[T], s_row: &[T]) -> T {
    debug_assert_eq!(w.len(), s_row.len());
    let mut mx = T::neg_infinity();
    for (&wj, &sj) in w.iter().zip(s_row) {
        let t = wj - sj;
        if t > mx {
            mx = t;
        }
    }
    if mx == T::neg_infinity() {
        return T::neg_infinity();
    }
    let mut acc = T::ZERO;
    for (&wj, &sj) in w.iter().zip(s_row) {
        acc += (wj - sj - mx).exp();
    }
    mx + acc.ln()
}

/// `Σ_j exp(φᵢ + ψ_j − S_ij)` — one plan-row mass without
/// materializing the plan. Precision-generic like [`lse_shifted`].
#[inline]
pub(crate) fn sum_exp_row<T: Scalar>(phi_i: T, psi: &[T], s_row: &[T]) -> T {
    let mut acc = T::ZERO;
    for (&pj, &sj) in psi.iter().zip(s_row) {
        acc += (phi_i + pj - sj).exp();
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sinkhorn::test_support::random_problem;

    #[test]
    fn extreme_epsilon_stays_finite() {
        let (cost, u, v) = random_problem(25, 18, 12);
        let opts = SinkhornOptions {
            epsilon: 5e-4, // range/ε ≈ 2·10³ — far past Gibbs viability
            max_iters: 30000,
            tolerance: 1e-9,
            check_every: 50,
        };
        let r = sinkhorn_log(&cost, &u, &v, &opts).unwrap();
        assert!(r.plan.all_finite());
        assert!(r.marginal_error < 1e-6, "err={}", r.marginal_error);
    }

    #[test]
    fn tiny_epsilon_approaches_monge_map_mass() {
        // With ε → 0 on a 1D-convex cost the plan concentrates: max
        // entry per row should carry almost all of that row's mass.
        let n = 12;
        let cost = Mat::from_fn(n, n, |i, j| {
            let d = i as f64 - j as f64;
            d * d / (n * n) as f64
        });
        let u = vec![1.0 / n as f64; n];
        let v = vec![1.0 / n as f64; n];
        let opts = SinkhornOptions {
            epsilon: 1e-5,
            max_iters: 20000,
            tolerance: 1e-12,
            check_every: 50,
        };
        let r = sinkhorn_log(&cost, &u, &v, &opts).unwrap();
        for i in 0..n {
            let row_max = r.plan.row(i).iter().cloned().fold(0.0, f64::max);
            assert!(
                row_max > 0.95 / n as f64,
                "row {i} not concentrated: max={row_max}"
            );
        }
    }

    #[test]
    fn zero_mass_marginal_entry_zeroes_row() {
        let (cost, mut u, v) = random_problem(6, 6, 9);
        u[2] = 0.0;
        crate::linalg::normalize_l1(&mut u).unwrap();
        let mut v2 = v.clone();
        crate::linalg::normalize_l1(&mut v2).unwrap();
        let opts = SinkhornOptions {
            epsilon: 0.01,
            max_iters: 5000,
            tolerance: 1e-11,
            check_every: 10,
        };
        let r = sinkhorn_log(&cost, &u, &v2, &opts).unwrap();
        let _ = v;
        for j in 0..6 {
            assert_eq!(r.plan[(2, j)], 0.0);
        }
        assert!(r.marginal_error < 1e-7);
    }

    #[test]
    fn parallel_sweeps_match_serial_bitwise() {
        // Potential updates are block-exact: any thread count must
        // reproduce the serial plan bitwise.
        let (cost, u, v) = random_problem(160, 48, 31);
        // tolerance 0 ⇒ fixed sweep budget on every path, so the
        // comparison is exact rather than stopping-time dependent.
        let opts = SinkhornOptions {
            epsilon: 0.01,
            max_iters: 300,
            tolerance: 0.0,
            check_every: 10,
        };
        let serial = sinkhorn_log(&cost, &u, &v, &opts).unwrap();
        for threads in [2usize, 4, 7] {
            let mut ws = SinkhornWorkspace::new(160, 48, Parallelism::new(threads));
            let mut plan = Mat::zeros(160, 48);
            let (_, err) = log_into(&cost, &u, &v, &opts, &mut ws, &mut plan).unwrap();
            let d = crate::linalg::frobenius_diff(&plan, &serial.plan).unwrap();
            assert!(d < 1e-13, "threads={threads}: {d:e}");
            assert!((err - serial.marginal_error).abs() < 1e-13);
        }
    }
}
