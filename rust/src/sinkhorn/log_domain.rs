//! Log-domain (stabilized) Sinkhorn.
//!
//! Works on scaled dual potentials `φ = f/ε`, `ψ = g/ε` against the
//! scaled cost `S = Π/ε`:
//!
//! ```text
//! φ_i ← log u_i − LSE_j (ψ_j − S_ij)
//! ψ_j ← log v_j − LSE_i (φ_i − S_ij)
//! Γ_ij = exp(φ_i + ψ_j − S_ij)
//! ```
//!
//! Every log-sum-exp is max-shifted, so arbitrarily small ε (the
//! paper's 0.002 with O(1) costs ⇒ exponents ≈ −1000) cannot
//! under/overflow. Zero-mass marginal entries map to `φ = −∞`, which
//! correctly zeroes the corresponding plan row/column.

use super::{marginal_violation, validate, SinkhornOptions, SinkhornResult};
use crate::error::{Error, Result};
use crate::linalg::Mat;

/// Balanced Sinkhorn with log-domain stabilization.
pub fn sinkhorn_log(
    cost: &Mat,
    u: &[f64],
    v: &[f64],
    opts: &SinkhornOptions,
) -> Result<SinkhornResult> {
    validate(cost, u, v, opts)?;
    let (m, n) = cost.shape();
    let inv_eps = 1.0 / opts.epsilon;
    let s = cost.map(|c| c * inv_eps);
    let st = s.transpose();

    let log_u: Vec<f64> = u.iter().map(|&x| x.ln()).collect(); // ln 0 = −inf is fine
    let log_v: Vec<f64> = v.iter().map(|&x| x.ln()).collect();
    let mut phi = vec![0.0f64; m];
    let mut psi = vec![0.0f64; n];

    let mut iterations = 0;
    for it in 0..opts.max_iters {
        iterations = it + 1;
        // φ update: rows of S are contiguous.
        for i in 0..m {
            phi[i] = log_u[i] - lse_shifted(&psi, s.row(i));
        }
        // ψ update: rows of Sᵀ are contiguous.
        for j in 0..n {
            psi[j] = log_v[j] - lse_shifted(&phi, st.row(j));
        }
        if it % opts.check_every == opts.check_every - 1 {
            // Row-marginal violation: after the ψ update columns are
            // exact; rows drift by the same mechanism as Gibbs.
            let mut err = 0.0;
            for i in 0..m {
                let row_mass = sum_exp_row(phi[i], &psi, s.row(i));
                err += (row_mass - u[i]).abs();
            }
            if err < opts.tolerance {
                break;
            }
        }
    }

    let plan = build_plan(&phi, &psi, &s);
    if !plan.all_finite() {
        return Err(Error::Numeric("log sinkhorn produced non-finite plan".into()));
    }
    let marginal_error = marginal_violation(&plan, u, v);
    Ok(SinkhornResult {
        plan,
        iterations,
        marginal_error,
    })
}

/// `log Σ_j exp(w_j − s_j)` with max-shift; returns −∞ on empty /
/// all −∞ input (handled by the caller via `ln u = −∞` semantics).
#[inline]
fn lse_shifted(w: &[f64], s_row: &[f64]) -> f64 {
    debug_assert_eq!(w.len(), s_row.len());
    let mut mx = f64::NEG_INFINITY;
    for (wj, sj) in w.iter().zip(s_row) {
        let t = wj - sj;
        if t > mx {
            mx = t;
        }
    }
    if mx == f64::NEG_INFINITY {
        return f64::NEG_INFINITY;
    }
    let mut acc = 0.0;
    for (wj, sj) in w.iter().zip(s_row) {
        acc += (wj - sj - mx).exp();
    }
    mx + acc.ln()
}

/// `Σ_j exp(φᵢ + ψ_j − S_ij)` — one plan-row mass without
/// materializing the plan.
#[inline]
fn sum_exp_row(phi_i: f64, psi: &[f64], s_row: &[f64]) -> f64 {
    let mut acc = 0.0;
    for (pj, sj) in psi.iter().zip(s_row) {
        acc += (phi_i + pj - sj).exp();
    }
    acc
}

fn build_plan(phi: &[f64], psi: &[f64], s: &Mat) -> Mat {
    let (m, n) = s.shape();
    Mat::from_fn(m, n, |i, j| (phi[i] + psi[j] - s[(i, j)]).exp())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sinkhorn::test_support::random_problem;

    #[test]
    fn extreme_epsilon_stays_finite() {
        let (cost, u, v) = random_problem(25, 18, 12);
        let opts = SinkhornOptions {
            epsilon: 5e-4, // range/ε ≈ 2·10³ — far past Gibbs viability
            max_iters: 30000,
            tolerance: 1e-9,
            check_every: 50,
        };
        let r = sinkhorn_log(&cost, &u, &v, &opts).unwrap();
        assert!(r.plan.all_finite());
        assert!(r.marginal_error < 1e-6, "err={}", r.marginal_error);
    }

    #[test]
    fn tiny_epsilon_approaches_monge_map_mass() {
        // With ε → 0 on a 1D-convex cost the plan concentrates: max
        // entry per row should carry almost all of that row's mass.
        let n = 12;
        let cost = Mat::from_fn(n, n, |i, j| {
            let d = i as f64 - j as f64;
            d * d / (n * n) as f64
        });
        let u = vec![1.0 / n as f64; n];
        let v = vec![1.0 / n as f64; n];
        let opts = SinkhornOptions {
            epsilon: 1e-5,
            max_iters: 20000,
            tolerance: 1e-12,
            check_every: 50,
        };
        let r = sinkhorn_log(&cost, &u, &v, &opts).unwrap();
        for i in 0..n {
            let row_max = r.plan.row(i).iter().cloned().fold(0.0, f64::max);
            assert!(
                row_max > 0.95 / n as f64,
                "row {i} not concentrated: max={row_max}"
            );
        }
    }

    #[test]
    fn zero_mass_marginal_entry_zeroes_row() {
        let (cost, mut u, v) = random_problem(6, 6, 9);
        u[2] = 0.0;
        crate::linalg::normalize_l1(&mut u).unwrap();
        let mut v2 = v.clone();
        crate::linalg::normalize_l1(&mut v2).unwrap();
        let opts = SinkhornOptions {
            epsilon: 0.01,
            max_iters: 5000,
            tolerance: 1e-11,
            check_every: 10,
        };
        let r = sinkhorn_log(&cost, &u, &v2, &opts).unwrap();
        let _ = v;
        for j in 0..6 {
            assert_eq!(r.plan[(2, j)], 0.0);
        }
        assert!(r.marginal_error < 1e-7);
    }
}
