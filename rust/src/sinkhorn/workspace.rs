//! Persistent Sinkhorn workspace.
//!
//! The mirror-descent loop solves one entropic-OT subproblem per outer
//! iteration over matrices of a fixed `M×N` shape. Rebuilding the
//! kernel matrix, scaling vectors and reduction scratch each time put
//! the allocator on the hot path; [`SinkhornWorkspace`] owns every
//! buffer the Gibbs and log-domain sweeps touch so that
//! [`super::solve_into`] performs **zero heap allocation per outer
//! iteration** (asserted by `tests/alloc_hotpath.rs`).
//!
//! The workspace also caches the [`super::pick_regime`] decision: the
//! regime scan is an extra `O(MN)` pass, and the cost matrices of
//! consecutive mirror-descent iterations share their conditioning, so
//! the decision is made once per solve ([`EntropicGw`] resets it via
//! [`SinkhornWorkspace::reset_regime`]) instead of every iteration. If
//! a cached Gibbs choice underflows mid-solve (a kernel row/column
//! flushing to zero is caught by the sweeps themselves) the workspace
//! demotes itself to the log domain for the rest of the solve — the
//! same fallback the stateless [`super::solve`] performs per call.
//! The deliberate tradeoff vs the old per-iteration rescan: a later
//! iteration whose cost range drifts *into* the denormal margin
//! (row-gap/ε between the 600 threshold and the ~745 flush point)
//! stays on Gibbs with reduced precision instead of re-routing to the
//! log domain; the threshold's ~47-decade headroom exists precisely to
//! make that zone numerically survivable (see [`super::pick_regime`]).
//!
//! [`EntropicGw`]: crate::gw::EntropicGw

use super::Regime;
use crate::linalg::Mat;
use crate::parallel::Parallelism;

/// Reusable buffers for [`super::solve_into`] (one per solver/job;
/// not shareable across shapes).
#[derive(Debug)]
pub struct SinkhornWorkspace {
    pub(crate) m: usize,
    pub(crate) n: usize,
    pub(crate) par: Parallelism,
    /// Gibbs kernel `K` or scaled cost `S = Π/ε`, `m×n`.
    pub(crate) kernel: Mat,
    /// `Sᵀ` for the log-domain ψ sweep (`n×m`; built lazily so pure
    /// Gibbs workloads never pay for it).
    pub(crate) kernel_t: Option<Mat>,
    /// Row scalings `a` / potentials `φ` (length `m`).
    pub(crate) a: Vec<f64>,
    /// Column scalings `b` / potentials `ψ` (length `n`).
    pub(crate) b: Vec<f64>,
    /// `Kᵀ·a` / column-marginal scratch (length `n`).
    pub(crate) kta: Vec<f64>,
    /// `ln u` (length `m`).
    pub(crate) log_u: Vec<f64>,
    /// `ln v` (length `n`).
    pub(crate) log_v: Vec<f64>,
    /// Per-block `Kᵀa` partials for the parallel fused sweep
    /// (`threads × n`).
    pub(crate) partials: Vec<f64>,
    /// Per-block scalar partials for error reductions (`threads`).
    pub(crate) reduce: Vec<f64>,
    /// Cached numeric-regime decision for the current solve.
    regime: Option<Regime>,
    /// One-shot warm-start flag: the next [`super::solve_into`] reuses
    /// the Gibbs-form column duals currently in `b` instead of the
    /// cold `b = 1` / `ψ = 0` start (the log sweep translates with
    /// `ψ = ln b`). Armed by the f32→f64 refinement handoff
    /// (`gw::precision::F32Lane::refine_seed_into`); never set on the
    /// default path, so pure-f64 solves stay bitwise identical.
    warm_duals: bool,
}

impl SinkhornWorkspace {
    /// Allocate for `m×n` subproblems with the given thread budget.
    pub fn new(m: usize, n: usize, par: Parallelism) -> Self {
        let threads = par.threads();
        SinkhornWorkspace {
            m,
            n,
            par,
            kernel: Mat::zeros(m, n),
            kernel_t: None,
            a: vec![0.0; m],
            b: vec![0.0; n],
            kta: vec![0.0; n],
            log_u: vec![0.0; m],
            log_v: vec![0.0; n],
            partials: vec![0.0; threads * n],
            reduce: vec![0.0; threads],
            regime: None,
            warm_duals: false,
        }
    }

    /// Subproblem shape this workspace serves.
    pub fn shape(&self) -> (usize, usize) {
        (self.m, self.n)
    }

    /// Thread budget the sweeps run with.
    pub fn parallelism(&self) -> Parallelism {
        self.par
    }

    /// The regime cached for the current solve, if decided.
    pub fn cached_regime(&self) -> Option<Regime> {
        self.regime
    }

    /// Pin the regime for subsequent [`super::solve_into`] calls.
    pub fn set_regime(&mut self, regime: Regime) {
        self.regime = Some(regime);
    }

    /// Forget the cached regime — call at the start of each outer
    /// solve so a new cost scale gets a fresh `O(MN)` decision.
    pub fn reset_regime(&mut self) {
        self.regime = None;
    }

    /// Arm the next solve to start from the duals currently in `b`
    /// (Gibbs scaling form; see the `warm_duals` field). The caller
    /// writes the seed into `b` first.
    pub(crate) fn set_warm_duals(&mut self) {
        self.warm_duals = true;
    }

    /// Consume the warm-start flag (one-shot: the first sweep of the
    /// next solve takes it, every later subproblem starts cold).
    pub(crate) fn take_warm_duals(&mut self) -> bool {
        std::mem::take(&mut self.warm_duals)
    }

    /// Ensure the `Sᵀ` buffer exists (one allocation on the first
    /// log-domain subproblem; reused ever after).
    pub(crate) fn ensure_kernel_t(&mut self) {
        if self.kernel_t.is_none() {
            self.kernel_t = Some(Mat::zeros(self.n, self.m));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn regime_cache_lifecycle() {
        let mut ws = SinkhornWorkspace::new(4, 5, Parallelism::SERIAL);
        assert_eq!(ws.cached_regime(), None);
        ws.set_regime(Regime::Gibbs);
        assert_eq!(ws.cached_regime(), Some(Regime::Gibbs));
        ws.set_regime(Regime::Log);
        assert_eq!(ws.cached_regime(), Some(Regime::Log));
        ws.reset_regime();
        assert_eq!(ws.cached_regime(), None);
    }

    #[test]
    fn warm_dual_flag_is_one_shot() {
        let mut ws = SinkhornWorkspace::new(4, 5, Parallelism::SERIAL);
        assert!(!ws.take_warm_duals());
        ws.set_warm_duals();
        assert!(ws.take_warm_duals());
        assert!(!ws.take_warm_duals(), "flag must not persist");
    }

    #[test]
    fn buffers_sized_for_threads() {
        let ws = SinkhornWorkspace::new(10, 7, Parallelism::new(4));
        assert_eq!(ws.partials.len(), 4 * 7);
        assert_eq!(ws.reduce.len(), 4);
        assert_eq!(ws.shape(), (10, 7));
        assert!(ws.kernel_t.is_none());
    }
}
