//! Sinkhorn solvers for the entropic-OT subproblem (paper §2.1).
//!
//! Each mirror-descent iteration solves
//! `argmin_{Γ∈S(u,v)} ⟨Π, Γ⟩ + ε H(Γ)`, whose solution is
//! `Γ = diag(a) K diag(b)`, `K = exp(−Π/ε)`, with `a, b` fixed by the
//! marginals — computed by Sinkhorn matrix scaling in `O(MN)` per
//! sweep.
//!
//! Two numeric regimes:
//! * [`sinkhorn_gibbs`] — scaling in the exponential domain with the
//!   global min shifted out (absorbed into `a`; fast, adequate while
//!   `range(Π)/ε ≲ 680`).
//! * [`sinkhorn_log`] — stabilized dual potentials with streaming
//!   log-sum-exp (handles the paper's `ε = 0.002` settings, where raw
//!   Gibbs kernels underflow f64).
//!
//! [`sinkhorn_unbalanced`] implements the KL-relaxed scaling used by
//! UGW (Remark 2.3). The dispatching entry point [`solve`] picks
//! Gibbs/log automatically; FGC and the dense baseline always share
//! the same Sinkhorn path, so plan differences isolate the gradient
//! computation.

mod gibbs;
mod log_domain;
mod unbalanced;
mod workspace;

pub use gibbs::sinkhorn_gibbs;
pub use log_domain::sinkhorn_log;
// Precision-generic sweep cores, shared with the f32 serving lane
// (`crate::gw::precision`).
pub(crate) use gibbs::{fused_scaling_sweep, safe_div};
pub(crate) use log_domain::{lse_shifted, sum_exp_row};
pub use unbalanced::{sinkhorn_unbalanced, unbalanced_into, UnbalancedOptions, UnbalancedWorkspace};
pub use workspace::SinkhornWorkspace;

use crate::error::{Error, Result};
use crate::linalg::Mat;

/// Options shared by the Sinkhorn variants.
#[derive(Clone, Copy, Debug)]
pub struct SinkhornOptions {
    /// Entropic regularization ε.
    pub epsilon: f64,
    /// Maximum scaling sweeps.
    pub max_iters: usize,
    /// L1 marginal-violation tolerance for early stopping.
    pub tolerance: f64,
    /// Check the stopping criterion every `check_every` sweeps
    /// (the check itself costs an extra `O(MN)` pass).
    pub check_every: usize,
}

impl Default for SinkhornOptions {
    fn default() -> Self {
        SinkhornOptions {
            epsilon: 1e-2,
            max_iters: 2000,
            tolerance: 1e-9,
            check_every: 10,
        }
    }
}

/// Outcome of a Sinkhorn solve.
#[derive(Clone, Debug)]
pub struct SinkhornResult {
    /// The transport plan `Γ = diag(a) K diag(b)`.
    pub plan: Mat,
    /// Sweeps actually performed.
    pub iterations: usize,
    /// Final L1 marginal violation.
    pub marginal_error: f64,
}

/// Which numeric regime a cost matrix needs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Regime {
    /// Exponential-domain scaling is safe.
    Gibbs,
    /// Log-domain stabilization required.
    Log,
}

/// Decide the regime. Individual Gibbs-kernel entries may underflow
/// harmlessly (they represent genuinely negligible couplings); the
/// scaling only breaks when an entire *row or column* of
/// `K = exp(−(Π − min Π)/ε)` flushes to zero. The relevant exponent
/// is therefore the worst row/column *gap* `min_row(Π) − min(Π)`, not
/// the full range — this is what lets the paper's ε = 0.002 settings
/// run in the fast exponential domain.
pub fn pick_regime(cost: &Mat, epsilon: f64) -> Regime {
    let mut col_min = vec![f64::INFINITY; cost.cols()];
    pick_regime_scratch(cost, epsilon, &mut col_min)
}

/// [`pick_regime`] with a caller-provided column-min scratch
/// (≥ `cols`; fully overwritten) — the allocation-free form
/// [`solve_into`] runs on, so regime re-decisions per inner solve
/// (COOT resets the cache every subproblem) stay off the allocator.
pub(crate) fn pick_regime_scratch(cost: &Mat, epsilon: f64, col_scratch: &mut [f64]) -> Regime {
    let (m, n) = cost.shape();
    let global_min = cost.min();
    let mut worst_row_gap: f64 = 0.0;
    let col_min = &mut col_scratch[..n];
    col_min.fill(f64::INFINITY);
    for i in 0..m {
        let row = cost.row(i);
        let mut rmin = f64::INFINITY;
        for (j, &x) in row.iter().enumerate() {
            if x < rmin {
                rmin = x;
            }
            if x < col_min[j] {
                col_min[j] = x;
            }
        }
        worst_row_gap = worst_row_gap.max(rmin - global_min);
    }
    let worst_col_gap = col_min
        .iter()
        .map(|&c| c - global_min)
        .fold(0.0f64, f64::max);
    // e^−600 ≈ 2e−261 leaves ~47 decades of headroom above the f64
    // subnormal floor for the scaling products.
    if worst_row_gap.max(worst_col_gap) / epsilon > 600.0 {
        Regime::Log
    } else {
        Regime::Gibbs
    }
}

/// Solve the entropic-OT subproblem, dispatching on [`pick_regime`];
/// if the Gibbs path underflows anyway (adversarial cost structure),
/// retry once in the log domain rather than failing the solve.
///
/// Stateless convenience form — allocates fresh buffers and rescans
/// the regime every call. The mirror-descent loop uses [`solve_into`]
/// with a persistent [`SinkhornWorkspace`] instead.
pub fn solve(cost: &Mat, u: &[f64], v: &[f64], opts: &SinkhornOptions) -> Result<SinkhornResult> {
    validate(cost, u, v, opts)?;
    match pick_regime(cost, opts.epsilon) {
        Regime::Gibbs => match sinkhorn_gibbs(cost, u, v, opts) {
            Err(Error::Numeric(_)) => sinkhorn_log(cost, u, v, opts),
            other => other,
        },
        Regime::Log => sinkhorn_log(cost, u, v, opts),
    }
}

/// Outcome of a workspace solve (the plan lands in the caller's
/// buffer, so only scalars travel back).
#[derive(Clone, Copy, Debug)]
pub struct SinkhornStats {
    /// Sweeps actually performed.
    pub iterations: usize,
    /// Final L1 marginal violation.
    pub marginal_error: f64,
    /// Numeric regime the solve ran in.
    pub regime: Regime,
    /// True when a cached/forced Gibbs decision underflowed and the
    /// solve was retried in the log domain — the signal the serving
    /// layer's degradation ladder and fault counters key off.
    pub fell_back: bool,
}

/// Workspace form of [`solve`]: the plan is written into `plan`, all
/// intermediates live in `ws`, and the `O(MN)` [`pick_regime`] scan
/// runs only when the workspace has no cached decision (the
/// mirror-descent loop resets the cache once per *solve*, not per
/// outer iteration). Zero heap allocation on the success path.
///
/// If a cached Gibbs decision underflows mid-solve, the workspace is
/// demoted to the log domain for the remainder of the solve and the
/// subproblem is retried there — mirroring [`solve`]'s fallback.
pub fn solve_into(
    cost: &Mat,
    u: &[f64],
    v: &[f64],
    opts: &SinkhornOptions,
    ws: &mut SinkhornWorkspace,
    plan: &mut Mat,
) -> Result<SinkhornStats> {
    validate(cost, u, v, opts)?;
    if ws.shape() != cost.shape() {
        return Err(Error::shape(
            "sinkhorn::solve_into (workspace)",
            format!("{:?}", cost.shape()),
            format!("{:?}", ws.shape()),
        ));
    }
    if plan.shape() != cost.shape() {
        return Err(Error::shape(
            "sinkhorn::solve_into (plan)",
            format!("{:?}", cost.shape()),
            format!("{:?}", plan.shape()),
        ));
    }
    let regime = match ws.cached_regime() {
        Some(r) => r,
        None => {
            // `kta` is free until the sweeps (which fully re-initialize
            // it), so the regime scan borrows it instead of allocating.
            let r = pick_regime_scratch(cost, opts.epsilon, &mut ws.kta);
            ws.set_regime(r);
            r
        }
    };
    match regime {
        Regime::Gibbs => match gibbs::gibbs_into(cost, u, v, opts, ws, plan) {
            Ok((iterations, marginal_error)) => Ok(SinkhornStats {
                iterations,
                marginal_error,
                regime: Regime::Gibbs,
                fell_back: false,
            }),
            Err(Error::Numeric(_)) => {
                ws.set_regime(Regime::Log);
                let (iterations, marginal_error) =
                    log_domain::log_into(cost, u, v, opts, ws, plan)?;
                Ok(SinkhornStats {
                    iterations,
                    marginal_error,
                    regime: Regime::Log,
                    fell_back: true,
                })
            }
            Err(e) => Err(e),
        },
        Regime::Log => {
            let (iterations, marginal_error) = log_domain::log_into(cost, u, v, opts, ws, plan)?;
            Ok(SinkhornStats {
                iterations,
                marginal_error,
                regime: Regime::Log,
                fell_back: false,
            })
        }
    }
}

pub(crate) fn validate(cost: &Mat, u: &[f64], v: &[f64], opts: &SinkhornOptions) -> Result<()> {
    if cost.rows() != u.len() || cost.cols() != v.len() {
        return Err(Error::shape(
            "sinkhorn",
            format!("{}x{}", u.len(), v.len()),
            format!("{:?}", cost.shape()),
        ));
    }
    if opts.epsilon <= 0.0 {
        return Err(Error::Invalid(format!(
            "epsilon must be > 0, got {}",
            opts.epsilon
        )));
    }
    if u.iter().any(|&x| x < 0.0) || v.iter().any(|&x| x < 0.0) {
        return Err(Error::Invalid("marginals must be non-negative".into()));
    }
    if !cost.all_finite() {
        return Err(Error::Numeric(
            "cost matrix contains non-finite entries".into(),
        ));
    }
    Ok(())
}

/// L1 distance between the plan's row/col marginals and `(u, v)` —
/// the invariant every balanced solver must drive to ~0.
pub fn marginal_violation(plan: &Mat, u: &[f64], v: &[f64]) -> f64 {
    let r = plan.row_sums();
    let c = plan.col_sums();
    let eu: f64 = r.iter().zip(u).map(|(&a, &b)| (a - b).abs()).sum();
    let ev: f64 = c.iter().zip(v).map(|(&a, &b)| (a - b).abs()).sum();
    eu + ev
}

/// [`marginal_violation`] without the two marginal allocations:
/// `col_scratch` (≥ `cols`) holds the column sums, rows stream in one
/// pass. Same summation order as the allocating form, so results are
/// bitwise identical.
pub(crate) fn marginal_error_scratch(
    plan: &Mat,
    u: &[f64],
    v: &[f64],
    col_scratch: &mut [f64],
) -> f64 {
    let (m, n) = plan.shape();
    debug_assert!(col_scratch.len() >= n);
    let col = &mut col_scratch[..n];
    col.fill(0.0);
    // Row and column errors accumulate separately and are added once
    // at the end — the same grouping as the allocating form, so the
    // two are bitwise identical.
    let mut row_err = 0.0;
    for i in 0..m {
        let row = plan.row(i);
        let mut rs = 0.0;
        for (c, &x) in col.iter_mut().zip(row) {
            *c += x;
            rs += x;
        }
        row_err += (rs - u[i]).abs();
    }
    let mut col_err = 0.0;
    for (c, &vj) in col.iter().zip(v) {
        col_err += (c - vj).abs();
    }
    row_err + col_err
}

#[cfg(test)]
pub(crate) mod test_support {
    use super::*;
    use crate::linalg::normalize_l1;
    use crate::prng::Rng;

    pub fn random_problem(m: usize, n: usize, seed: u64) -> (Mat, Vec<f64>, Vec<f64>) {
        let mut rng = Rng::seeded(seed);
        let cost = Mat::from_fn(m, n, |_, _| rng.uniform());
        let mut u = rng.uniform_vec(m);
        let mut v = rng.uniform_vec(n);
        normalize_l1(&mut u).unwrap();
        normalize_l1(&mut v).unwrap();
        (cost, u, v)
    }
}

#[cfg(test)]
mod tests {
    use super::test_support::random_problem;
    use super::*;

    #[test]
    fn dispatch_matches_between_regimes() {
        // On a well-conditioned problem Gibbs and log-domain must agree.
        let (cost, u, v) = random_problem(20, 25, 5);
        let opts = SinkhornOptions {
            epsilon: 0.05,
            max_iters: 5000,
            tolerance: 1e-12,
            check_every: 5,
        };
        let g = sinkhorn_gibbs(&cost, &u, &v, &opts).unwrap();
        let l = sinkhorn_log(&cost, &u, &v, &opts).unwrap();
        let diff = crate::linalg::frobenius_diff(&g.plan, &l.plan).unwrap();
        assert!(diff < 1e-8, "gibbs vs log diff = {diff}");
    }

    #[test]
    fn regime_picker() {
        let cost = Mat::from_fn(4, 4, |i, j| (i + j) as f64); // range 6
        assert_eq!(pick_regime(&cost, 1.0), Regime::Gibbs);
        assert_eq!(pick_regime(&cost, 0.001), Regime::Log);
    }

    #[test]
    fn solve_tiny_epsilon_is_stable() {
        // The paper's ε=0.002 regime: dispatch must route to log-domain
        // and produce finite plans with correct marginals.
        let (cost, u, v) = random_problem(30, 30, 11);
        let opts = SinkhornOptions {
            epsilon: 0.002,
            max_iters: 20000,
            tolerance: 1e-10,
            check_every: 20,
        };
        let r = solve(&cost, &u, &v, &opts).unwrap();
        assert!(r.plan.all_finite());
        assert!(marginal_violation(&r.plan, &u, &v) < 1e-7);
    }

    #[test]
    fn solve_into_matches_solve_and_caches_regime() {
        let (cost, u, v) = random_problem(30, 28, 21);
        let opts = SinkhornOptions {
            epsilon: 0.05,
            max_iters: 4000,
            tolerance: 1e-12,
            check_every: 5,
        };
        let base = solve(&cost, &u, &v, &opts).unwrap();
        let mut ws = SinkhornWorkspace::new(30, 28, crate::parallel::Parallelism::SERIAL);
        let mut plan = Mat::zeros(30, 28);
        let s1 = solve_into(&cost, &u, &v, &opts, &mut ws, &mut plan).unwrap();
        assert_eq!(ws.cached_regime(), Some(s1.regime));
        assert!(crate::linalg::frobenius_diff(&plan, &base.plan).unwrap() < 1e-12);
        // Second call reuses the cached regime and the same buffers.
        let s2 = solve_into(&cost, &u, &v, &opts, &mut ws, &mut plan).unwrap();
        assert_eq!(s1.regime, s2.regime);
        assert!(crate::linalg::frobenius_diff(&plan, &base.plan).unwrap() < 1e-12);
        assert!((s2.marginal_error - s1.marginal_error).abs() < 1e-14);
        // Shape-mismatched workspace is rejected.
        let mut small = SinkhornWorkspace::new(4, 4, crate::parallel::Parallelism::SERIAL);
        assert!(solve_into(&cost, &u, &v, &opts, &mut small, &mut plan).is_err());
    }

    #[test]
    fn mispredicted_gibbs_regime_demotes_and_reports_fallback() {
        // Seed the workspace with a wrong (Gibbs) decision on a
        // problem that needs the log domain: the solve must demote,
        // succeed, report `fell_back`, and cache the corrected regime
        // — the recovery path the serving layer's fault-injection
        // harness exercises end-to-end.
        let mut rng = crate::prng::Rng::seeded(13);
        let cost = Mat::from_fn(16, 16, |i, j| 10.0 * ((i * 16 + j) as f64) + rng.uniform());
        let (_, u, v) = random_problem(16, 16, 13);
        let opts = SinkhornOptions {
            epsilon: 0.002,
            max_iters: 20000,
            tolerance: 1e-9,
            check_every: 10,
        };
        assert_eq!(pick_regime(&cost, opts.epsilon), Regime::Log);
        let mut ws = SinkhornWorkspace::new(16, 16, crate::parallel::Parallelism::SERIAL);
        ws.set_regime(Regime::Gibbs);
        let mut plan = Mat::zeros(16, 16);
        let stats = solve_into(&cost, &u, &v, &opts, &mut ws, &mut plan).unwrap();
        assert!(stats.fell_back, "forced misprediction must demote");
        assert_eq!(stats.regime, Regime::Log);
        assert_eq!(ws.cached_regime(), Some(Regime::Log));
        assert!(plan.all_finite());
        assert!(marginal_violation(&plan, &u, &v) < 1e-7);
    }

    #[test]
    fn warm_started_duals_accelerate_and_stay_correct() {
        // Seeding the next solve with converged Gibbs duals must cut
        // the sweep count and land on the same plan — the f32→f64
        // refinement handoff contract.
        let (cost, u, v) = random_problem(24, 20, 41);
        let opts = SinkhornOptions {
            epsilon: 0.05,
            max_iters: 4000,
            tolerance: 1e-12,
            check_every: 1,
        };
        let mut ws = SinkhornWorkspace::new(24, 20, crate::parallel::Parallelism::SERIAL);
        let mut plan = Mat::zeros(24, 20);
        let cold = solve_into(&cost, &u, &v, &opts, &mut ws, &mut plan).unwrap();
        assert_eq!(cold.regime, Regime::Gibbs);
        // `ws.b` still holds the converged duals; re-solve warm.
        ws.set_warm_duals();
        let mut plan2 = Mat::zeros(24, 20);
        let warm = solve_into(&cost, &u, &v, &opts, &mut ws, &mut plan2).unwrap();
        assert!(
            warm.iterations < cold.iterations,
            "warm {} vs cold {}",
            warm.iterations,
            cold.iterations
        );
        assert!(crate::linalg::frobenius_diff(&plan, &plan2).unwrap() < 1e-9);
        // The flag is one-shot: a third solve is bitwise the cold one.
        let mut plan3 = Mat::zeros(24, 20);
        let third = solve_into(&cost, &u, &v, &opts, &mut ws, &mut plan3).unwrap();
        assert_eq!(third.iterations, cold.iterations);
        assert_eq!(plan.as_slice(), plan3.as_slice());
    }

    #[test]
    fn warm_seed_in_log_regime_stays_correct() {
        // An arbitrary positive Gibbs-form seed in the log regime must
        // not corrupt the converged answer (ψ = ln b translation).
        let (cost, u, v) = random_problem(16, 16, 42);
        let opts = SinkhornOptions {
            epsilon: 0.002,
            max_iters: 20000,
            tolerance: 1e-10,
            check_every: 10,
        };
        let reference = solve(&cost, &u, &v, &opts).unwrap();
        let mut ws = SinkhornWorkspace::new(16, 16, crate::parallel::Parallelism::SERIAL);
        assert_eq!(pick_regime(&cost, opts.epsilon), Regime::Log);
        ws.b.fill(0.5);
        ws.set_warm_duals();
        let mut plan = Mat::zeros(16, 16);
        let stats = solve_into(&cost, &u, &v, &opts, &mut ws, &mut plan).unwrap();
        assert_eq!(stats.regime, Regime::Log);
        assert!(crate::linalg::frobenius_diff(&plan, &reference.plan).unwrap() < 1e-8);
        assert!(marginal_violation(&plan, &u, &v) < 1e-7);
    }

    #[test]
    fn scratch_marginal_error_matches_allocating_form() {
        let (cost, u, v) = random_problem(9, 13, 2);
        let r = solve(&cost, &u, &v, &SinkhornOptions::default()).unwrap();
        let mut scratch = vec![0.0; 13];
        let a = marginal_violation(&r.plan, &u, &v);
        let b = marginal_error_scratch(&r.plan, &u, &v, &mut scratch);
        assert_eq!(a, b);
    }

    #[test]
    fn validation_rejects_bad_inputs() {
        let (cost, u, v) = random_problem(4, 5, 1);
        let opts = SinkhornOptions {
            epsilon: 0.0,
            ..SinkhornOptions::default()
        };
        assert!(solve(&cost, &u, &v, &opts).is_err());
        let opts = SinkhornOptions::default();
        assert!(solve(&cost, &u[..3], &v, &opts).is_err());
        let mut un = u.clone();
        un[0] = -0.1;
        assert!(solve(&cost, &un, &v, &opts).is_err());
    }
}
