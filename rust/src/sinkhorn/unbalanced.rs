//! Unbalanced Sinkhorn scaling (Chizat et al. 2018), the inner solver
//! for UGW (paper Remark 2.3).
//!
//! Solves `min_Γ ⟨C, Γ⟩ + ε KL(Γ | u⊗v) + ρ KL(Γ1 | u) + ρ KL(Γᵀ1 | v)`
//! by the fixed-point iteration on scalings of `K_ij = u_i v_j e^{−C_ij/ε}`:
//!
//! ```text
//! a ← (u ⊘ K b)^{ρ/(ρ+ε)} ,   b ← (v ⊘ Kᵀ a)^{ρ/(ρ+ε)} .
//! ```
//!
//! Unlike the balanced case the marginals are only *pulled toward*
//! `(u, v)` with strength `ρ`; mass is created/destroyed as the KL
//! penalties allow. `ρ → ∞` recovers balanced Sinkhorn.
//!
//! [`unbalanced_into`] is the workspace form the UGW mirror-descent
//! driver calls every outer iteration: the kernel, its transpose and
//! the scaling vectors live in an [`UnbalancedWorkspace`], the plan
//! lands in the caller's buffer, and the `K·b` / `Kᵀ·a` products run
//! over row blocks on the workspace's thread budget (each row is an
//! independent dot product, so results are bitwise identical across
//! thread counts). The stateless [`sinkhorn_unbalanced`] delegates to
//! it, so the two forms agree bitwise.

use super::SinkhornResult;
use crate::error::{Error, Result};
use crate::linalg::Mat;
use crate::parallel::{self, Parallelism};

/// Options for the unbalanced scaling loop.
#[derive(Clone, Copy, Debug)]
pub struct UnbalancedOptions {
    /// Entropic regularization ε.
    pub epsilon: f64,
    /// Marginal KL penalty ρ.
    pub rho: f64,
    /// Maximum sweeps.
    pub max_iters: usize,
    /// Early-stop when the scaling vectors move less than this (L∞ on log a).
    pub tolerance: f64,
}

impl Default for UnbalancedOptions {
    fn default() -> Self {
        UnbalancedOptions {
            epsilon: 1e-2,
            rho: 1.0,
            max_iters: 2000,
            tolerance: 1e-10,
        }
    }
}

/// Reusable buffers for [`unbalanced_into`] (one per solver/job; not
/// shareable across shapes).
#[derive(Debug)]
pub struct UnbalancedWorkspace {
    m: usize,
    n: usize,
    par: Parallelism,
    /// Gibbs kernel with the reference measure folded in:
    /// `K_ij = e^{−C_ij/ε}·u_i v_j` (`m×n`).
    kernel: Mat,
    /// `Kᵀ` (`n×m`) so both scaling products stream contiguous rows.
    kernel_t: Mat,
    /// Row scalings `a` (length `m`).
    a: Vec<f64>,
    /// Column scalings `b` (length `n`).
    b: Vec<f64>,
    /// `K·b` (length `m`).
    kb: Vec<f64>,
    /// `Kᵀ·a` (length `n`); doubles as the marginal-error scratch.
    kta: Vec<f64>,
}

impl UnbalancedWorkspace {
    /// Allocate for `m×n` subproblems with the given thread budget.
    pub fn new(m: usize, n: usize, par: Parallelism) -> Self {
        UnbalancedWorkspace {
            m,
            n,
            par,
            kernel: Mat::zeros(m, n),
            kernel_t: Mat::zeros(n, m),
            a: vec![0.0; m],
            b: vec![0.0; n],
            kb: vec![0.0; m],
            kta: vec![0.0; n],
        }
    }

    /// Subproblem shape this workspace serves.
    pub fn shape(&self) -> (usize, usize) {
        (self.m, self.n)
    }

    /// Thread budget the scaling products run with.
    pub fn parallelism(&self) -> Parallelism {
        self.par
    }
}

/// Unbalanced entropic scaling. `u`, `v` are arbitrary non-negative
/// mass vectors (not necessarily probabilities).
///
/// Stateless convenience form — allocates fresh buffers every call.
/// The UGW driver uses [`unbalanced_into`] with a persistent
/// [`UnbalancedWorkspace`] instead.
pub fn sinkhorn_unbalanced(
    cost: &Mat,
    u: &[f64],
    v: &[f64],
    opts: &UnbalancedOptions,
) -> Result<SinkhornResult> {
    let (m, n) = cost.shape();
    let mut ws = UnbalancedWorkspace::new(m, n, Parallelism::SERIAL);
    let mut plan = Mat::zeros(m, n);
    let (iterations, marginal_error) = unbalanced_into(cost, u, v, opts, &mut ws, &mut plan)?;
    Ok(SinkhornResult {
        plan,
        iterations,
        marginal_error,
    })
}

/// Workspace form of [`sinkhorn_unbalanced`]: the plan is written into
/// `plan`, every intermediate lives in `ws`, and the per-sweep matvecs
/// run on the workspace's thread budget. Zero heap allocation on the
/// success path. Returns `(iterations, marginal_error)`.
pub fn unbalanced_into(
    cost: &Mat,
    u: &[f64],
    v: &[f64],
    opts: &UnbalancedOptions,
    ws: &mut UnbalancedWorkspace,
    plan: &mut Mat,
) -> Result<(usize, f64)> {
    let (m, n) = cost.shape();
    if u.len() != m || v.len() != n {
        return Err(Error::shape(
            "sinkhorn_unbalanced",
            format!("{}x{}", u.len(), v.len()),
            format!("{m}x{n}"),
        ));
    }
    if ws.shape() != (m, n) {
        return Err(Error::shape(
            "unbalanced_into (workspace)",
            format!("{m}x{n}"),
            format!("{:?}", ws.shape()),
        ));
    }
    if plan.shape() != (m, n) {
        return Err(Error::shape(
            "unbalanced_into (plan)",
            format!("{m}x{n}"),
            format!("{:?}", plan.shape()),
        ));
    }
    if opts.epsilon <= 0.0 || opts.rho <= 0.0 {
        return Err(Error::Invalid(format!(
            "epsilon and rho must be > 0 (got ε={}, ρ={})",
            opts.epsilon, opts.rho
        )));
    }
    // NOTE: unlike balanced Sinkhorn, a global cost shift is NOT
    // neutral here — the absolute cost level decides how much mass the
    // KL penalties let the plan shed. Use the raw Gibbs kernel; the
    // caller picks ε large enough that exp(−max(C)/ε) stays normal.
    let inv_eps = 1.0 / opts.epsilon;
    let par = ws.par;
    let min_rows = parallel::min_rows_for(n.max(1));
    // Reference measure u⊗v folded into K (row-parallel; the grouping
    // `exp(−C/ε)·(u_i·v_j)` matches the historical two-pass build
    // bitwise).
    let cs = cost.as_slice();
    parallel::for_row_blocks(par, m, n, min_rows, ws.kernel.as_mut_slice(), |_bl, rr, kblk| {
        for (local, i) in rr.enumerate() {
            let ui = u[i];
            let src = &cs[i * n..(i + 1) * n];
            let dst = &mut kblk[local * n..(local + 1) * n];
            for ((d, &c), &vj) in dst.iter_mut().zip(src).zip(v) {
                *d = (-c * inv_eps).exp() * (ui * vj);
            }
        }
    });
    ws.kernel.transpose_into(&mut ws.kernel_t)?;

    let fe = opts.rho / (opts.rho + opts.epsilon);
    ws.a.fill(1.0);
    ws.b.fill(1.0);

    let min_rows_n = parallel::min_rows_for(m.max(1));
    let mut iterations = 0;
    for it in 0..opts.max_iters {
        iterations = it + 1;
        let mut delta = 0.0f64;
        {
            let (k, b) = (&ws.kernel, &ws.b);
            parallel::for_row_blocks(par, m, 1, min_rows, &mut ws.kb, |_bl, rr, out| {
                for (local, i) in rr.enumerate() {
                    out[local] = crate::linalg::dot(k.row(i), b);
                }
            });
        }
        for i in 0..m {
            let new = if ws.kb[i] > 0.0 {
                (u[i] / ws.kb[i]).powf(fe)
            } else {
                0.0
            };
            delta = delta.max((new.max(1e-300).ln() - ws.a[i].max(1e-300).ln()).abs());
            ws.a[i] = new;
        }
        {
            let (kt, a) = (&ws.kernel_t, &ws.a);
            parallel::for_row_blocks(par, n, 1, min_rows_n, &mut ws.kta, |_bl, rr, out| {
                for (local, j) in rr.enumerate() {
                    out[local] = crate::linalg::dot(kt.row(j), a);
                }
            });
        }
        for j in 0..n {
            ws.b[j] = if ws.kta[j] > 0.0 {
                (v[j] / ws.kta[j]).powf(fe)
            } else {
                0.0
            };
        }
        if delta < opts.tolerance {
            break;
        }
    }

    {
        let (k, a, b) = (&ws.kernel, &ws.a, &ws.b);
        parallel::for_row_blocks(par, m, n, min_rows, plan.as_mut_slice(), |_bl, rr, pblk| {
            for (local, i) in rr.enumerate() {
                let ai = a[i];
                let krow = k.row(i);
                let prow = &mut pblk[local * n..(local + 1) * n];
                for ((p, &kij), &bj) in prow.iter_mut().zip(krow).zip(b) {
                    *p = ai * kij * bj;
                }
            }
        });
    }
    if !plan.all_finite() {
        return Err(Error::Numeric(
            "unbalanced sinkhorn produced non-finite plan".into(),
        ));
    }
    let marginal_error = super::marginal_error_scratch(plan, u, v, &mut ws.kta);
    Ok((iterations, marginal_error))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sinkhorn::test_support::random_problem;
    use crate::sinkhorn::{sinkhorn_gibbs, SinkhornOptions};

    #[test]
    fn large_rho_recovers_balanced() {
        let (cost, u, v) = random_problem(12, 14, 21);
        let ub = sinkhorn_unbalanced(
            &cost,
            &u,
            &v,
            &UnbalancedOptions {
                epsilon: 0.05,
                rho: 1e5,
                max_iters: 20000,
                tolerance: 1e-13,
            },
        )
        .unwrap();
        let bal = sinkhorn_gibbs(
            &cost,
            &u,
            &v,
            &SinkhornOptions {
                epsilon: 0.05,
                max_iters: 20000,
                tolerance: 1e-13,
                check_every: 10,
            },
        )
        .unwrap();
        let diff = crate::linalg::frobenius_diff(&ub.plan, &bal.plan).unwrap();
        assert!(diff < 1e-3, "diff={diff}");
    }

    #[test]
    fn small_rho_sheds_mass_under_expensive_cost() {
        // With an expensive uniform cost and weak marginal pull the
        // optimal plan transports less than the full mass.
        let m = 6;
        let cost = Mat::full(m, m, 5.0);
        let u = vec![1.0 / m as f64; m];
        let v = vec![1.0 / m as f64; m];
        let r = sinkhorn_unbalanced(
            &cost,
            &u,
            &v,
            &UnbalancedOptions {
                epsilon: 0.05,
                rho: 0.1,
                max_iters: 5000,
                tolerance: 1e-12,
            },
        )
        .unwrap();
        assert!(r.plan.total() < 0.5, "mass={}", r.plan.total());
        assert!(r.plan.total() > 0.0);
    }

    #[test]
    fn workspace_form_matches_stateless_bitwise() {
        let (cost, u, v) = random_problem(11, 9, 33);
        let opts = UnbalancedOptions {
            epsilon: 0.05,
            rho: 0.7,
            max_iters: 800,
            tolerance: 1e-12,
        };
        let base = sinkhorn_unbalanced(&cost, &u, &v, &opts).unwrap();
        let mut ws = UnbalancedWorkspace::new(11, 9, Parallelism::SERIAL);
        let mut plan = Mat::zeros(11, 9);
        // Two passes through one workspace: both must equal the
        // stateless solve exactly (the workspace fully re-initializes).
        for _ in 0..2 {
            let (iters, err) = unbalanced_into(&cost, &u, &v, &opts, &mut ws, &mut plan).unwrap();
            assert_eq!(iters, base.iterations);
            assert_eq!(err, base.marginal_error);
            assert_eq!(plan.as_slice(), base.plan.as_slice());
        }
        // Shape-mismatched workspace is rejected.
        let mut small = UnbalancedWorkspace::new(4, 4, Parallelism::SERIAL);
        assert!(unbalanced_into(&cost, &u, &v, &opts, &mut small, &mut plan).is_err());
    }

    #[test]
    fn parallel_matches_serial_bitwise() {
        let (cost, u, v) = random_problem(120, 40, 55);
        let opts = UnbalancedOptions {
            epsilon: 0.05,
            rho: 1.0,
            max_iters: 300,
            tolerance: 0.0,
        };
        let serial = sinkhorn_unbalanced(&cost, &u, &v, &opts).unwrap();
        for threads in [2usize, 4, 7] {
            let mut ws = UnbalancedWorkspace::new(120, 40, Parallelism::new(threads));
            let mut plan = Mat::zeros(120, 40);
            let (_, err) = unbalanced_into(&cost, &u, &v, &opts, &mut ws, &mut plan).unwrap();
            // Row-dot decomposition: no cross-block reduction anywhere,
            // so every thread count reproduces the serial bits.
            assert_eq!(plan.as_slice(), serial.plan.as_slice(), "threads={threads}");
            assert_eq!(err, serial.marginal_error);
        }
    }

    #[test]
    fn rejects_bad_params() {
        let (cost, u, v) = random_problem(4, 4, 2);
        let mut o = UnbalancedOptions::default();
        o.rho = 0.0;
        assert!(sinkhorn_unbalanced(&cost, &u, &v, &o).is_err());
    }
}
