//! Unbalanced Sinkhorn scaling (Chizat et al. 2018), the inner solver
//! for UGW (paper Remark 2.3).
//!
//! Solves `min_Γ ⟨C, Γ⟩ + ε KL(Γ | u⊗v) + ρ KL(Γ1 | u) + ρ KL(Γᵀ1 | v)`
//! by the fixed-point iteration on scalings of `K_ij = u_i v_j e^{−C_ij/ε}`:
//!
//! ```text
//! a ← (u ⊘ K b)^{ρ/(ρ+ε)} ,   b ← (v ⊘ Kᵀ a)^{ρ/(ρ+ε)} .
//! ```
//!
//! Unlike the balanced case the marginals are only *pulled toward*
//! `(u, v)` with strength `ρ`; mass is created/destroyed as the KL
//! penalties allow. `ρ → ∞` recovers balanced Sinkhorn.

use super::SinkhornResult;
use crate::error::{Error, Result};
use crate::linalg::Mat;

/// Options for the unbalanced scaling loop.
#[derive(Clone, Copy, Debug)]
pub struct UnbalancedOptions {
    /// Entropic regularization ε.
    pub epsilon: f64,
    /// Marginal KL penalty ρ.
    pub rho: f64,
    /// Maximum sweeps.
    pub max_iters: usize,
    /// Early-stop when the scaling vectors move less than this (L∞ on log a).
    pub tolerance: f64,
}

impl Default for UnbalancedOptions {
    fn default() -> Self {
        UnbalancedOptions {
            epsilon: 1e-2,
            rho: 1.0,
            max_iters: 2000,
            tolerance: 1e-10,
        }
    }
}

/// Unbalanced entropic scaling. `u`, `v` are arbitrary non-negative
/// mass vectors (not necessarily probabilities).
pub fn sinkhorn_unbalanced(
    cost: &Mat,
    u: &[f64],
    v: &[f64],
    opts: &UnbalancedOptions,
) -> Result<SinkhornResult> {
    let (m, n) = cost.shape();
    if u.len() != m || v.len() != n {
        return Err(Error::shape(
            "sinkhorn_unbalanced",
            format!("{}x{}", u.len(), v.len()),
            format!("{m}x{n}"),
        ));
    }
    if opts.epsilon <= 0.0 || opts.rho <= 0.0 {
        return Err(Error::Invalid(format!(
            "epsilon and rho must be > 0 (got ε={}, ρ={})",
            opts.epsilon, opts.rho
        )));
    }
    // NOTE: unlike balanced Sinkhorn, a global cost shift is NOT
    // neutral here — the absolute cost level decides how much mass the
    // KL penalties let the plan shed. Use the raw Gibbs kernel; the
    // caller picks ε large enough that exp(−max(C)/ε) stays normal.
    let inv_eps = 1.0 / opts.epsilon;
    // Reference measure u⊗v folded into K.
    let mut k = cost.map(|c| (-c * inv_eps).exp());
    for i in 0..m {
        let row = k.row_mut(i);
        for (j, x) in row.iter_mut().enumerate() {
            *x *= u[i] * v[j];
        }
    }
    let kt = k.transpose();

    let fe = opts.rho / (opts.rho + opts.epsilon);
    let mut a = vec![1.0f64; m];
    let mut b = vec![1.0f64; n];
    let mut kb = vec![0.0f64; m];
    let mut kta = vec![0.0f64; n];

    let mut iterations = 0;
    for it in 0..opts.max_iters {
        iterations = it + 1;
        let mut delta = 0.0f64;
        for (i, o) in kb.iter_mut().enumerate() {
            *o = crate::linalg::dot(k.row(i), &b);
        }
        for i in 0..m {
            let new = if kb[i] > 0.0 { (u[i] / kb[i]).powf(fe) } else { 0.0 };
            delta = delta.max((new.max(1e-300).ln() - a[i].max(1e-300).ln()).abs());
            a[i] = new;
        }
        for (j, o) in kta.iter_mut().enumerate() {
            *o = crate::linalg::dot(kt.row(j), &a);
        }
        for j in 0..n {
            b[j] = if kta[j] > 0.0 { (v[j] / kta[j]).powf(fe) } else { 0.0 };
        }
        if delta < opts.tolerance {
            break;
        }
    }

    let plan = Mat::from_fn(m, n, |i, j| a[i] * k[(i, j)] * b[j]);
    if !plan.all_finite() {
        return Err(Error::Numeric("unbalanced sinkhorn produced non-finite plan".into()));
    }
    let marginal_error = super::marginal_violation(&plan, u, v);
    Ok(SinkhornResult {
        plan,
        iterations,
        marginal_error,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sinkhorn::test_support::random_problem;
    use crate::sinkhorn::{sinkhorn_gibbs, SinkhornOptions};

    #[test]
    fn large_rho_recovers_balanced() {
        let (cost, u, v) = random_problem(12, 14, 21);
        let ub = sinkhorn_unbalanced(
            &cost,
            &u,
            &v,
            &UnbalancedOptions {
                epsilon: 0.05,
                rho: 1e5,
                max_iters: 20000,
                tolerance: 1e-13,
            },
        )
        .unwrap();
        let bal = sinkhorn_gibbs(
            &cost,
            &u,
            &v,
            &SinkhornOptions {
                epsilon: 0.05,
                max_iters: 20000,
                tolerance: 1e-13,
                check_every: 10,
            },
        )
        .unwrap();
        let diff = crate::linalg::frobenius_diff(&ub.plan, &bal.plan).unwrap();
        assert!(diff < 1e-3, "diff={diff}");
    }

    #[test]
    fn small_rho_sheds_mass_under_expensive_cost() {
        // With an expensive uniform cost and weak marginal pull the
        // optimal plan transports less than the full mass.
        let m = 6;
        let cost = Mat::full(m, m, 5.0);
        let u = vec![1.0 / m as f64; m];
        let v = vec![1.0 / m as f64; m];
        let r = sinkhorn_unbalanced(
            &cost,
            &u,
            &v,
            &UnbalancedOptions {
                epsilon: 0.05,
                rho: 0.1,
                max_iters: 5000,
                tolerance: 1e-12,
            },
        )
        .unwrap();
        assert!(r.plan.total() < 0.5, "mass={}", r.plan.total());
        assert!(r.plan.total() > 0.0);
    }

    #[test]
    fn rejects_bad_params() {
        let (cost, u, v) = random_problem(4, 4, 2);
        let mut o = UnbalancedOptions::default();
        o.rho = 0.0;
        assert!(sinkhorn_unbalanced(&cost, &u, &v, &o).is_err());
    }
}
