//! Exponential-domain Sinkhorn scaling.
//!
//! `K = exp(−(Π − min Π)/ε)` (the global shift is a diagonal-free
//! constant factor absorbed into `a`), then alternate
//! `a ← u ⊘ (K b)`, `b ← v ⊘ (Kᵀ a)` until the marginals match.
//! Cost per sweep: two `O(MN)` matvecs over a matrix that is built
//! once. This is the paper's (and POT's) workhorse; for
//! `range(Π)/ε ≳ 680` use [`super::sinkhorn_log`].

use super::{marginal_violation, validate, SinkhornOptions, SinkhornResult};
use crate::error::{Error, Result};
use crate::linalg::Mat;

/// Balanced Sinkhorn in the Gibbs (exponential) domain.
pub fn sinkhorn_gibbs(
    cost: &Mat,
    u: &[f64],
    v: &[f64],
    opts: &SinkhornOptions,
) -> Result<SinkhornResult> {
    validate(cost, u, v, opts)?;
    let (m, n) = cost.shape();
    let shift = cost.min();
    let inv_eps = 1.0 / opts.epsilon;
    // Gibbs kernel, built once per subproblem. Both scaling products
    // stream the same row-major K: `K·b` as row dot-products, `Kᵀ·a`
    // as row-scaled accumulation — no transpose copy (§Perf: saves an
    // N² build + N² resident bytes per subproblem).
    let k = cost.map(|c| (-(c - shift) * inv_eps).exp());

    let mut a = vec![1.0f64; m];
    let mut b = vec![1.0f64; n];
    let mut kb = vec![0.0f64; m];
    let mut kta = vec![0.0f64; n];

    let mut iterations = 0;
    for it in 0..opts.max_iters {
        iterations = it + 1;
        // One fused pass over K per sweep (§Perf: the sweep is
        // memory-bound on K, so reading it once instead of twice is
        // ~2× on large problems): per row compute `kb_i = K_i·b`
        // (Gauss-Seidel: old b), update `a_i`, and immediately
        // accumulate `a_i·K_i` into `kta`.
        kta.fill(0.0);
        for i in 0..m {
            let row = k.row(i);
            let kbi = crate::linalg::dot(row, &b);
            kb[i] = kbi;
            let ai = safe_div(u[i], kbi, "Kb")?;
            a[i] = ai;
            if ai != 0.0 {
                crate::linalg::axpy(ai, row, &mut kta);
            }
        }
        for j in 0..n {
            b[j] = safe_div(v[j], kta[j], "Kᵀa")?;
        }
        if it % opts.check_every == opts.check_every - 1 {
            // After a b-update columns are exact; only rows can violate.
            matvec_into(&k, &b, &mut kb);
            let err: f64 = (0..m).map(|i| (a[i] * kb[i] - u[i]).abs()).sum();
            if err < opts.tolerance {
                break;
            }
        }
    }

    let plan = Mat::from_fn(m, n, |i, j| a[i] * k[(i, j)] * b[j]);
    if !plan.all_finite() {
        return Err(Error::Numeric(
            "gibbs sinkhorn produced non-finite plan (try log-domain)".into(),
        ));
    }
    let marginal_error = marginal_violation(&plan, u, v);
    Ok(SinkhornResult {
        plan,
        iterations,
        marginal_error,
    })
}

#[inline]
fn matvec_into(k: &Mat, x: &[f64], out: &mut [f64]) {
    for (i, o) in out.iter_mut().enumerate() {
        *o = crate::linalg::dot(k.row(i), x);
    }
}

#[inline]
fn safe_div(num: f64, den: f64, what: &str) -> Result<f64> {
    if den <= 0.0 || !den.is_finite() {
        if num == 0.0 {
            // A zero-mass marginal entry legitimately zeroes the scaling.
            return Ok(0.0);
        }
        return Err(Error::Numeric(format!(
            "sinkhorn underflow: {what} entry = {den} (cost range too large for Gibbs domain)"
        )));
    }
    Ok(num / den)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sinkhorn::test_support::random_problem;

    #[test]
    fn marginals_converge() {
        let (cost, u, v) = random_problem(15, 22, 3);
        let opts = SinkhornOptions {
            epsilon: 0.1,
            max_iters: 3000,
            tolerance: 1e-12,
            check_every: 10,
        };
        let r = sinkhorn_gibbs(&cost, &u, &v, &opts).unwrap();
        assert!(r.marginal_error < 1e-9, "err={}", r.marginal_error);
        assert!(r.iterations < 3000);
    }

    #[test]
    fn plan_is_nonnegative() {
        let (cost, u, v) = random_problem(10, 10, 4);
        let r = sinkhorn_gibbs(&cost, &u, &v, &SinkhornOptions::default()).unwrap();
        assert!(r.plan.as_slice().iter().all(|&x| x >= 0.0));
    }

    #[test]
    fn large_epsilon_recovers_independent_coupling() {
        // ε → ∞ makes the entropic term dominate: Γ → u vᵀ.
        let (cost, u, v) = random_problem(8, 9, 6);
        let opts = SinkhornOptions {
            epsilon: 1e4,
            max_iters: 2000,
            tolerance: 1e-13,
            check_every: 5,
        };
        let r = sinkhorn_gibbs(&cost, &u, &v, &opts).unwrap();
        for i in 0..8 {
            for j in 0..9 {
                let want = u[i] * v[j];
                assert!(
                    (r.plan[(i, j)] - want).abs() < 1e-5,
                    "({i},{j}): {} vs {want}",
                    r.plan[(i, j)]
                );
            }
        }
    }

    #[test]
    fn underflow_detected_not_silent() {
        // ε far too small for Gibbs: must error (or converge), never NaN.
        let (cost, u, v) = random_problem(12, 12, 8);
        let opts = SinkhornOptions {
            epsilon: 1e-5,
            max_iters: 50,
            tolerance: 1e-9,
            check_every: 10,
        };
        match sinkhorn_gibbs(&cost, &u, &v, &opts) {
            Ok(r) => assert!(r.plan.all_finite()),
            Err(e) => assert!(e.to_string().contains("underflow") || e.to_string().contains("non-finite")),
        }
    }
}
