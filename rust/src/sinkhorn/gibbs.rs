//! Exponential-domain Sinkhorn scaling.
//!
//! `K = exp(−(Π − min Π)/ε)` (the global shift is a diagonal-free
//! constant factor absorbed into `a`), then alternate
//! `a ← u ⊘ (K b)`, `b ← v ⊘ (Kᵀ a)` until the marginals match.
//! Cost per sweep: two `O(MN)` matvecs over a matrix that is built
//! once. This is the paper's (and POT's) workhorse; for
//! `range(Π)/ε ≳ 680` use [`super::sinkhorn_log`].
//!
//! The sweep is row-parallel: each contiguous row block computes its
//! `K·b` dot products and `a` updates, plus a block-local `Kᵀa`
//! partial that the calling thread folds in ascending block order
//! (the one reduction in the solver — agreement across thread counts
//! is at accumulation roundoff, ≤ 1e-12 relative; everything else is
//! block-exact). With one block the code path degenerates to the
//! original fused serial sweep, accumulating straight into `kta`.

use super::workspace::SinkhornWorkspace;
use super::{validate, SinkhornOptions, SinkhornResult};
use crate::error::{Error, Result};
use crate::linalg::Mat;
use crate::parallel::{self, Parallelism};
use crate::scalar::Scalar;
use std::sync::atomic::{AtomicBool, Ordering};

/// Balanced Sinkhorn in the Gibbs (exponential) domain.
pub fn sinkhorn_gibbs(
    cost: &Mat,
    u: &[f64],
    v: &[f64],
    opts: &SinkhornOptions,
) -> Result<SinkhornResult> {
    validate(cost, u, v, opts)?;
    let (m, n) = cost.shape();
    let mut ws = SinkhornWorkspace::new(m, n, Parallelism::SERIAL);
    let mut plan = Mat::zeros(m, n);
    let (iterations, marginal_error) = gibbs_into(cost, u, v, opts, &mut ws, &mut plan)?;
    Ok(SinkhornResult {
        plan,
        iterations,
        marginal_error,
    })
}

/// Workspace form of [`sinkhorn_gibbs`]: zero heap allocation on the
/// success path, plan written into `plan`. Returns
/// `(iterations, marginal_error)`.
pub(super) fn gibbs_into(
    cost: &Mat,
    u: &[f64],
    v: &[f64],
    opts: &SinkhornOptions,
    ws: &mut SinkhornWorkspace,
    plan: &mut Mat,
) -> Result<(usize, f64)> {
    let (m, n) = cost.shape();
    debug_assert_eq!((ws.m, ws.n), (m, n));
    let shift = cost.min();
    let inv_eps = 1.0 / opts.epsilon;
    let warm = ws.take_warm_duals();
    let SinkhornWorkspace {
        kernel,
        a,
        b,
        kta,
        partials,
        reduce,
        par,
        ..
    } = ws;
    let par = *par;
    let min_rows = parallel::min_rows_for(n.max(1));

    // Gibbs kernel, built once per subproblem into the workspace. Both
    // scaling products stream the same row-major K: `K·b` as row
    // dot-products, `Kᵀ·a` as row-scaled accumulation — no transpose
    // copy (§Perf: saves an N² build + N² resident bytes per
    // subproblem).
    let cs = cost.as_slice();
    parallel::for_row_blocks(par, m, n, min_rows, kernel.as_mut_slice(), |_bl, rr, kblk| {
        let src = &cs[rr.start * n..rr.end * n];
        for (d, &c) in kblk.iter_mut().zip(src) {
            *d = (-(c - shift) * inv_eps).exp();
        }
    });
    let k = &*kernel;

    a.fill(1.0);
    // Warm start: keep the seeded column duals (the first fused sweep
    // immediately Gauss-Seidels `a` against them); cold start is the
    // historical `b = 1`.
    if !warm {
        b.fill(1.0);
    }

    let mut iterations = 0;
    for it in 0..opts.max_iters {
        iterations = it + 1;
        // One fused pass over K per sweep (§Perf: the sweep is
        // memory-bound on K, so reading it once instead of twice is
        // ~2× on large problems): per row compute `(K·b)_i`
        // (Gauss-Seidel: old b), update `a_i`, and accumulate
        // `a_i·K_i` into the block's `kta` partial.
        fused_scaling_sweep(k.as_slice(), u, b, a, kta, partials, par, min_rows)?;
        for j in 0..n {
            b[j] = safe_div(v[j], kta[j], "Kᵀa")?;
        }
        if it % opts.check_every == opts.check_every - 1 {
            // After a b-update columns are exact; only rows can violate.
            let (ar, br) = (&*a, &*b);
            let err = parallel::sum_blocks(par, m, min_rows, reduce, |_bl, rr| {
                let mut e = 0.0;
                for i in rr {
                    e += (ar[i] * crate::linalg::dot(k.row(i), br) - u[i]).abs();
                }
                e
            });
            if err < opts.tolerance {
                break;
            }
        }
    }

    let (ar, br) = (&*a, &*b);
    parallel::for_row_blocks(par, m, n, min_rows, plan.as_mut_slice(), |_bl, rr, pblk| {
        for (local, i) in rr.enumerate() {
            let ai = ar[i];
            let krow = k.row(i);
            let prow = &mut pblk[local * n..(local + 1) * n];
            for ((p, &kij), &bj) in prow.iter_mut().zip(krow).zip(br) {
                *p = ai * kij * bj;
            }
        }
    });
    if !plan.all_finite() {
        return Err(Error::Numeric(
            "gibbs sinkhorn produced non-finite plan (try log-domain)".into(),
        ));
    }
    let marginal_error = super::marginal_error_scratch(plan, u, v, kta);
    Ok((iterations, marginal_error))
}

/// The fused row pass: `a = u ⊘ (K·b)`, `kta = Kᵀ·a`, split over row
/// blocks. Block partials land in `partials` and are folded in
/// ascending block order; with one block the sweep accumulates
/// straight into `kta` — the exact original serial path.
/// Precision-generic over the row-major `m×n` kernel slice (`T = f64`
/// here by inference; the f32 serving lane streams the same core). The
/// hot `aᵢ·Kᵢ` accumulation is the `linalg::axpy` kernel, so the
/// `simd` feature's unrolled lanes apply to the sweep directly.
#[allow(clippy::too_many_arguments)]
pub(crate) fn fused_scaling_sweep<T: Scalar>(
    k: &[T],
    u: &[T],
    b: &[T],
    a: &mut [T],
    kta: &mut [T],
    partials: &mut [T],
    par: Parallelism,
    min_rows: usize,
) -> Result<()> {
    let m = u.len();
    let n = b.len();
    debug_assert_eq!(k.len(), m * n);
    let underflow = AtomicBool::new(false);
    let block = |rr: std::ops::Range<usize>, a_blk: &mut [T], p_blk: &mut [T]| {
        p_blk.fill(T::ZERO);
        for (local, i) in rr.enumerate() {
            let row = &k[i * n..(i + 1) * n];
            let kbi = crate::linalg::dot(row, b);
            let ai = if kbi > T::ZERO && kbi.finite() {
                u[i] / kbi
            } else if u[i] == T::ZERO {
                // A zero-mass marginal entry legitimately zeroes the
                // scaling.
                T::ZERO
            } else {
                underflow.store(true, Ordering::Relaxed);
                T::ZERO
            };
            a_blk[local] = ai;
            if ai != T::ZERO {
                crate::linalg::axpy(ai, row, p_blk);
            }
        }
    };

    let nb = par
        .blocks(m, min_rows)
        .min((partials.len() / n.max(1)).max(1));
    if nb <= 1 {
        block(0..m, a, kta);
    } else {
        std::thread::scope(|s| {
            let mut a_rest = a;
            let mut p_rest = &mut partials[..nb * n];
            for bidx in 0..nb {
                let rr = parallel::block_range(m, nb, bidx);
                let (a_blk, at) = std::mem::take(&mut a_rest).split_at_mut(rr.len());
                a_rest = at;
                let (p_blk, pt) = std::mem::take(&mut p_rest).split_at_mut(n);
                p_rest = pt;
                if bidx == nb - 1 {
                    block(rr, a_blk, p_blk);
                } else {
                    let f = &block;
                    s.spawn(move || f(rr, a_blk, p_blk));
                }
            }
        });
        kta.fill(T::ZERO);
        for bidx in 0..nb {
            let p = &partials[bidx * n..(bidx + 1) * n];
            for (t, &x) in kta.iter_mut().zip(p) {
                *t += x;
            }
        }
    }
    if underflow.load(Ordering::Relaxed) {
        return Err(Error::Numeric(
            "sinkhorn underflow: Kb entry vanished (cost range too large for Gibbs domain)".into(),
        ));
    }
    Ok(())
}

#[inline]
pub(crate) fn safe_div<T: Scalar>(num: T, den: T, what: &str) -> Result<T> {
    if den <= T::ZERO || !den.finite() {
        if num == T::ZERO {
            // A zero-mass marginal entry legitimately zeroes the scaling.
            return Ok(T::ZERO);
        }
        return Err(Error::Numeric(format!(
            "sinkhorn underflow: {what} entry = {} (cost range too large for Gibbs domain)",
            den.to_f64()
        )));
    }
    Ok(num / den)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sinkhorn::test_support::random_problem;

    #[test]
    fn marginals_converge() {
        let (cost, u, v) = random_problem(15, 22, 3);
        let opts = SinkhornOptions {
            epsilon: 0.1,
            max_iters: 3000,
            tolerance: 1e-12,
            check_every: 10,
        };
        let r = sinkhorn_gibbs(&cost, &u, &v, &opts).unwrap();
        assert!(r.marginal_error < 1e-9, "err={}", r.marginal_error);
        assert!(r.iterations < 3000);
    }

    #[test]
    fn plan_is_nonnegative() {
        let (cost, u, v) = random_problem(10, 10, 4);
        let r = sinkhorn_gibbs(&cost, &u, &v, &SinkhornOptions::default()).unwrap();
        assert!(r.plan.as_slice().iter().all(|&x| x >= 0.0));
    }

    #[test]
    fn large_epsilon_recovers_independent_coupling() {
        // ε → ∞ makes the entropic term dominate: Γ → u vᵀ.
        let (cost, u, v) = random_problem(8, 9, 6);
        let opts = SinkhornOptions {
            epsilon: 1e4,
            max_iters: 2000,
            tolerance: 1e-13,
            check_every: 5,
        };
        let r = sinkhorn_gibbs(&cost, &u, &v, &opts).unwrap();
        for i in 0..8 {
            for j in 0..9 {
                let want = u[i] * v[j];
                assert!(
                    (r.plan[(i, j)] - want).abs() < 1e-5,
                    "({i},{j}): {} vs {want}",
                    r.plan[(i, j)]
                );
            }
        }
    }

    #[test]
    fn underflow_detected_not_silent() {
        // ε far too small for Gibbs: must error (or converge), never NaN.
        let (cost, u, v) = random_problem(12, 12, 8);
        let opts = SinkhornOptions {
            epsilon: 1e-5,
            max_iters: 50,
            tolerance: 1e-9,
            check_every: 10,
        };
        match sinkhorn_gibbs(&cost, &u, &v, &opts) {
            Ok(r) => assert!(r.plan.all_finite()),
            Err(e) => assert!(e.to_string().contains("underflow") || e.to_string().contains("non-finite")),
        }
    }

    #[test]
    fn parallel_sweep_matches_serial() {
        // 300×40 splits into real blocks at the 4 KiB threshold;
        // tolerance 0 fixes the sweep budget so the comparison is not
        // stopping-time dependent.
        let (cost, u, v) = random_problem(300, 40, 17);
        let opts = SinkhornOptions {
            epsilon: 0.05,
            max_iters: 400,
            tolerance: 0.0,
            check_every: 10,
        };
        let serial = sinkhorn_gibbs(&cost, &u, &v, &opts).unwrap();
        for threads in [2usize, 4, 7] {
            let mut ws = SinkhornWorkspace::new(300, 40, Parallelism::new(threads));
            let mut plan = Mat::zeros(300, 40);
            let (iters, err) = gibbs_into(&cost, &u, &v, &opts, &mut ws, &mut plan).unwrap();
            // The Kᵀa reduction order differs across block counts, so
            // iteration counts may flip by one check window; the plans
            // themselves must agree to accumulation roundoff.
            assert!(iters <= opts.max_iters);
            let d = crate::linalg::frobenius_diff(&plan, &serial.plan).unwrap();
            assert!(d < 1e-12, "threads={threads}: plan diff {d:e}");
            assert!((err - serial.marginal_error).abs() < 1e-12);
        }
    }
}
