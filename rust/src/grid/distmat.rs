//! Dense distance-matrix builders.
//!
//! These materialize the `O(N²)` matrices the *original* (baseline)
//! entropic algorithm multiplies with — FGC never builds them on its
//! hot path, but the baseline, the tests and the `C₁` constant term
//! need them.

use super::{Grid1d, Grid2d, Grid3d};
use crate::linalg::Mat;

/// Dense 1D grid distance matrix `D_{ij} = h^k |i−j|^k` (paper eq. 2.2).
pub fn dense_dist_1d(grid: &Grid1d, k: u32) -> Mat {
    let scale = grid.scale(k);
    Mat::from_fn(grid.n, grid.n, |i, j| {
        let d = i.abs_diff(j) as f64;
        scale * d.powi(k as i32)
    })
}

/// Dense 2D grid distance matrix under the Manhattan metric,
/// `D_{ij} = h^k (|Δr| + |Δc|)^k` over flattened indices (paper eq. 3.10).
pub fn dense_dist_2d(grid: &Grid2d, k: u32) -> Mat {
    let n2 = grid.len();
    let scale = grid.scale(k);
    Mat::from_fn(n2, n2, |a, b| {
        let d = grid.manhattan(a, b) as f64;
        scale * d.powi(k as i32)
    })
}

/// Dense 3D grid distance matrix under the Manhattan metric,
/// `D_{ij} = h^k (|Δz| + |Δy| + |Δx|)^k` over flattened indices — the
/// `O(N²)`-memory oracle the 3D scan path is tested against (the fgc
/// path never materializes it).
pub fn dense_dist_3d(grid: &Grid3d, k: u32) -> Mat {
    let n3 = grid.len();
    let scale = grid.scale(k);
    Mat::from_fn(n3, n3, |a, b| {
        let d = grid.manhattan(a, b) as f64;
        scale * d.powi(k as i32)
    })
}

impl Grid3d {
    /// Dense distance matrix (test oracle; `O(N²)` memory) —
    /// convenience alias for [`dense_dist_3d`].
    pub fn dense(&self, k: u32) -> Mat {
        dense_dist_3d(self, k)
    }
}

/// Dense unscaled power-distance matrix `|i−j|^r` of size `n×n`, with
/// the `0^0 = 1` convention (so `r = 0` gives the all-ones matrix `J`
/// needed by the binomial expansion in §3.1).
pub fn dense_pow_dist(n: usize, r: u32) -> Mat {
    Mat::from_fn(n, n, |i, j| {
        let d = i.abs_diff(j) as f64;
        if r == 0 {
            1.0
        } else {
            d.powi(r as i32)
        }
    })
}

/// Dense helper for the constant term `C₁`: computes
/// `(D ⊙ D)·w` for a dense distance matrix `D` (used by tests to check
/// the FGC-accelerated version).
pub fn squared_dist_apply_dense(d: &Mat, w: &[f64]) -> Vec<f64> {
    let mut y = vec![0.0; d.rows()];
    squared_dist_apply_dense_into(d, w, &mut y);
    y
}

/// [`squared_dist_apply_dense`] into a caller-owned buffer (same
/// per-row summation order, so results are bitwise identical; no
/// allocation).
pub fn squared_dist_apply_dense_into(d: &Mat, w: &[f64], out: &mut [f64]) {
    assert_eq!(d.cols(), w.len());
    assert_eq!(d.rows(), out.len());
    for (i, o) in out.iter_mut().enumerate() {
        *o = d
            .row(i)
            .iter()
            .zip(w)
            .map(|(&dij, &wj)| dij * dij * wj)
            .sum();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dist_1d_values() {
        let g = Grid1d::new(4, 0.5);
        let d = dense_dist_1d(&g, 2);
        // h² |i−j|²; h=0.5 → h²=0.25
        assert_eq!(d[(0, 0)], 0.0);
        assert_eq!(d[(0, 3)], 0.25 * 9.0);
        assert_eq!(d[(2, 1)], 0.25);
        // symmetry
        for i in 0..4 {
            for j in 0..4 {
                assert_eq!(d[(i, j)], d[(j, i)]);
            }
        }
    }

    #[test]
    fn dist_2d_manhattan() {
        let g = Grid2d::new(3, 1.0);
        let d = dense_dist_2d(&g, 1);
        let a = g.flat(0, 0);
        let b = g.flat(2, 2);
        assert_eq!(d[(a, b)], 4.0);
        let c = g.flat(1, 0);
        assert_eq!(d[(a, c)], 1.0);
    }

    #[test]
    fn dist_2d_power_scaling() {
        let g = Grid2d::new(3, 2.0);
        let d = dense_dist_2d(&g, 2);
        let a = g.flat(0, 0);
        let b = g.flat(1, 2);
        // (h·(1+2))² with h^k pulled out as h²·3² = 4·9
        assert_eq!(d[(a, b)], 4.0 * 9.0);
    }

    #[test]
    fn dist_3d_manhattan() {
        let g = Grid3d::new(3, 0.5);
        let d = dense_dist_3d(&g, 2);
        let a = g.flat(0, 0, 0);
        let b = g.flat(2, 1, 2);
        // h² (2+1+2)² = 0.25 · 25
        assert_eq!(d[(a, b)], 0.25 * 25.0);
        assert_eq!(d[(a, b)], d[(b, a)]);
        assert_eq!(d[(a, a)], 0.0);
    }

    #[test]
    fn pow_dist_zero_power_is_ones() {
        let j = dense_pow_dist(3, 0);
        assert!(j.as_slice().iter().all(|&x| x == 1.0));
    }
}
