//! Pascal-triangle binomial coefficient table.
//!
//! The FGC recurrence (paper eq. 3.9) consumes `C(r−1, s−1)` for
//! `r ≤ k+1`; the 2D Kronecker expansion (eq. 3.12) consumes `C(k, r)`.
//! The table is built once in `O(k²)` (paper footnote 2) and shared.

/// Dense lower-triangular table of binomial coefficients as `f64`
/// (they enter floating-point recurrences directly).
#[derive(Clone, Debug)]
pub struct Binomial {
    /// `table[r][s] = C(r, s)` for `s ≤ r ≤ max_n`.
    table: Vec<Vec<f64>>,
}

impl Binomial {
    /// Build the triangle up to `C(max_n, ·)` inclusive.
    pub fn new(max_n: usize) -> Self {
        let mut table: Vec<Vec<f64>> = Vec::with_capacity(max_n + 1);
        for r in 0..=max_n {
            let mut row = vec![1.0; r + 1];
            for s in 1..r {
                row[s] = table[r - 1][s - 1] + table[r - 1][s];
            }
            table.push(row);
        }
        Binomial { table }
    }

    /// `C(n, k)`; zero when `k > n`.
    #[inline]
    pub fn c(&self, n: usize, k: usize) -> f64 {
        if k > n {
            0.0
        } else {
            self.table[n][k]
        }
    }

    /// Largest `n` available.
    pub fn max_n(&self) -> usize {
        self.table.len() - 1
    }

    /// Row `n` of the triangle: `[C(n,0), …, C(n,n)]`.
    pub fn row(&self, n: usize) -> &[f64] {
        &self.table[n]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_values() {
        let b = Binomial::new(10);
        assert_eq!(b.c(0, 0), 1.0);
        assert_eq!(b.c(4, 2), 6.0);
        assert_eq!(b.c(10, 5), 252.0);
        assert_eq!(b.c(7, 0), 1.0);
        assert_eq!(b.c(7, 7), 1.0);
        assert_eq!(b.c(3, 5), 0.0);
    }

    #[test]
    fn row_sums_are_powers_of_two() {
        let b = Binomial::new(20);
        for n in 0..=20usize {
            let s: f64 = b.row(n).iter().sum();
            assert_eq!(s, (1u64 << n) as f64);
        }
    }

    #[test]
    fn symmetry() {
        let b = Binomial::new(15);
        for n in 0..=15usize {
            for k in 0..=n {
                assert_eq!(b.c(n, k), b.c(n, n - k));
            }
        }
    }
}
