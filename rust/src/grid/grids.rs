//! Uniform grid descriptors.

/// A 1D uniform grid of `n` points with spacing `h`: support
/// `x_i = x₀ + i·h`. The paper's §4.1 grids are `x_i = (i−1)/(N−1)`,
/// i.e. `h = 1/(N−1)` on `[0,1]`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Grid1d {
    /// Number of grid points.
    pub n: usize,
    /// Spacing between adjacent points.
    pub h: f64,
}

impl Grid1d {
    /// Grid of `n` points with explicit spacing.
    pub fn new(n: usize, h: f64) -> Self {
        assert!(n >= 1 && h > 0.0, "Grid1d requires n≥1, h>0");
        Grid1d { n, h }
    }

    /// `n` points spanning `[0, 1]` (paper §4.1 convention).
    pub fn unit(n: usize) -> Self {
        assert!(n >= 2);
        Grid1d {
            n,
            h: 1.0 / (n as f64 - 1.0),
        }
    }

    /// The distance-scale factor `h^k` pulled out of `D = h^k · D̃`.
    #[inline]
    pub fn scale(&self, k: u32) -> f64 {
        self.h.powi(k as i32)
    }

    /// Point coordinates.
    pub fn points(&self) -> Vec<f64> {
        (0..self.n).map(|i| i as f64 * self.h).collect()
    }
}

/// A 2D uniform `n×n` grid with equal horizontal/vertical spacing `h`
/// (paper §3.1). Points are flattened row-by-row:
/// index `i = r·n + c` ↔ grid coordinate `(r, c)`, matching the
/// paper's `vec(Q) = (q₁₁ … q₁ₙ, q₂₁ …)` convention. The metric is
/// Manhattan: `d(i, j) = h^k (|Δr| + |Δc|)^k`, which is exactly what
/// makes the binomial Kronecker expansion (eq. 3.12) exact.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Grid2d {
    /// Side length (total points `N = n²`).
    pub n: usize,
    /// Spacing (both axes).
    pub h: f64,
}

impl Grid2d {
    /// `n×n` grid with explicit spacing.
    pub fn new(n: usize, h: f64) -> Self {
        assert!(n >= 1 && h > 0.0, "Grid2d requires n≥1, h>0");
        Grid2d { n, h }
    }

    /// `n×n` points spanning `[0,1]²` (paper §4.2 convention).
    pub fn unit(n: usize) -> Self {
        assert!(n >= 2);
        Grid2d {
            n,
            h: 1.0 / (n as f64 - 1.0),
        }
    }

    /// Total number of points `N = n²`.
    #[inline]
    pub fn len(&self) -> usize {
        self.n * self.n
    }

    /// True iff the grid is empty (never for validly constructed grids).
    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// `h^k`.
    #[inline]
    pub fn scale(&self, k: u32) -> f64 {
        self.h.powi(k as i32)
    }

    /// Flat index of grid coordinate `(row, col)`.
    #[inline]
    pub fn flat(&self, row: usize, col: usize) -> usize {
        debug_assert!(row < self.n && col < self.n);
        row * self.n + col
    }

    /// Grid coordinate of flat index.
    #[inline]
    pub fn coords(&self, idx: usize) -> (usize, usize) {
        (idx / self.n, idx % self.n)
    }

    /// Unscaled Manhattan distance between two flat indices.
    pub fn manhattan(&self, a: usize, b: usize) -> usize {
        let (ar, ac) = self.coords(a);
        let (br, bc) = self.coords(b);
        ar.abs_diff(br) + ac.abs_diff(bc)
    }
}

/// A 3D uniform `n×n×n` grid with equal spacing `h` on every axis —
/// the "higher dimensional space" generalization the paper sketches in
/// §3.1 ("there is no essential difference"). Points are flattened
/// `idx = (z·n + y)·n + x`, and the metric is Manhattan:
/// `d(i, j) = h^k (|Δz| + |Δy| + |Δx|)^k`, so the multinomial theorem
/// gives an exact Kronecker-of-scans expansion per axis (see
/// `crate::fgc::fgc3d`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Grid3d {
    /// Side length (total points `N = n³`).
    pub n: usize,
    /// Spacing (all axes).
    pub h: f64,
}

impl Grid3d {
    /// `n×n×n` grid with explicit spacing.
    pub fn new(n: usize, h: f64) -> Self {
        assert!(n >= 1 && h > 0.0, "Grid3d requires n≥1, h>0");
        Grid3d { n, h }
    }

    /// `n×n×n` points spanning `[0,1]³` (the 1D/2D unit convention).
    pub fn unit(n: usize) -> Self {
        assert!(n >= 2);
        Grid3d {
            n,
            h: 1.0 / (n as f64 - 1.0),
        }
    }

    /// Total number of points `N = n³`.
    #[inline]
    pub fn len(&self) -> usize {
        self.n * self.n * self.n
    }

    /// True iff the grid is empty (never for validly constructed grids).
    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// `h^k`.
    #[inline]
    pub fn scale(&self, k: u32) -> f64 {
        self.h.powi(k as i32)
    }

    /// Flat index of grid coordinate `(z, y, x)`.
    #[inline]
    pub fn flat(&self, z: usize, y: usize, x: usize) -> usize {
        debug_assert!(z < self.n && y < self.n && x < self.n);
        (z * self.n + y) * self.n + x
    }

    /// Grid coordinate `(z, y, x)` of a flat index.
    #[inline]
    pub fn coords(&self, idx: usize) -> (usize, usize, usize) {
        let n = self.n;
        (idx / (n * n), (idx / n) % n, idx % n)
    }

    /// Unscaled Manhattan distance between two flat indices.
    pub fn manhattan(&self, a: usize, b: usize) -> usize {
        let (az, ay, ax) = self.coords(a);
        let (bz, by, bx) = self.coords(b);
        az.abs_diff(bz) + ay.abs_diff(by) + ax.abs_diff(bx)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unit_grid_1d_matches_paper() {
        let g = Grid1d::unit(5);
        let pts = g.points();
        assert!((pts[4] - 1.0).abs() < 1e-15);
        assert!((pts[1] - 0.25).abs() < 1e-15);
        assert!((g.scale(2) - 0.0625).abs() < 1e-15);
    }

    #[test]
    fn grid2d_flat_roundtrip() {
        let g = Grid2d::new(7, 0.5);
        for idx in 0..g.len() {
            let (r, c) = g.coords(idx);
            assert_eq!(g.flat(r, c), idx);
        }
    }

    #[test]
    fn manhattan_distance() {
        let g = Grid2d::new(4, 1.0);
        let a = g.flat(0, 0);
        let b = g.flat(3, 2);
        assert_eq!(g.manhattan(a, b), 5);
        assert_eq!(g.manhattan(b, a), 5);
        assert_eq!(g.manhattan(a, a), 0);
    }

    #[test]
    fn grid3d_flat_roundtrip_and_manhattan() {
        let g = Grid3d::new(4, 1.0);
        assert_eq!(g.len(), 64);
        for idx in 0..g.len() {
            let (z, y, x) = g.coords(idx);
            assert_eq!(g.flat(z, y, x), idx);
        }
        let a = g.flat(0, 0, 0);
        let b = g.flat(3, 2, 1);
        assert_eq!(g.manhattan(a, b), 6);
        assert_eq!(g.manhattan(b, a), 6);
        assert_eq!(g.manhattan(a, a), 0);
        let u = Grid3d::unit(5);
        assert!((u.h - 0.25).abs() < 1e-15);
        assert!((u.scale(2) - 0.0625).abs() < 1e-15);
    }
}
