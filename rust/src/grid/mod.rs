//! Uniform grids, grid-structured distance matrices and binomial
//! tables — the structural assumptions behind FGC (paper §2, §3.1).

mod binomial;
mod distmat;
mod grids;

pub use binomial::Binomial;
pub use distmat::{
    dense_dist_1d, dense_dist_2d, dense_dist_3d, dense_pow_dist, squared_dist_apply_dense,
    squared_dist_apply_dense_into,
};
pub use grids::{Grid1d, Grid2d, Grid3d};
